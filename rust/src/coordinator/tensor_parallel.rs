//! Filter-dimension (KN) tensor parallelism: one layer across many chips.
//!
//! Layer-boundary sharding ([`super::sharding::ShardPlan`]) cannot help
//! when a *single* layer's weight registers exceed one chip's
//! [`ChipConfig::wreg_capacity`] — that model is simply rejected today.
//! The paper's Combined-Stationary mapping (§III-C) already parallelizes
//! a layer's KN filters across memory columns *inside* the chip; this
//! module extends the same split *across* chips:
//!
//! - [`TensorPlan`] cuts one layer's KN filters into contiguous per-chip
//!   slices.  A layer's register footprint is exactly linear in its
//!   filter count (`kn * j_dim * col_tiles`, and `col_tiles` is
//!   KN-independent), so near-equal KN slices are footprint-balanced by
//!   construction, and each slice is checked against one chip's capacity.
//! - [`TensorParallelSession`] serves a [`HybridPlan`] — a **pipeline of
//!   tensor-parallel groups**.  A `ways = 1` stage is exactly the
//!   familiar [`ChipSession`] shard; a `ways > 1` stage runs one resident
//!   single-layer `ChipSession` per (layer, slice) and, after every split
//!   layer, *all-gathers* the partial output feature maps: each chip's
//!   slice of channels circles a ring so every chip holds the full tensor
//!   for the next layer.  The gather is charged through
//!   [`HwParams::link_bytes_per_ns`] / [`HwParams::link_latency_ns`] into
//!   [`ChipMetrics::xfer_bytes`] / [`ChipMetrics::xfer_ns`] (and
//!   `xfer_legs`), with [`HwParams::wire_bytes`] adding the SECDED
//!   overhead when link ECC is armed.
//! - [`plan_auto`] is the latency-balanced auto-planner: it *simulates*
//!   each layer's per-chip latency at candidate split widths (compute
//!   costs are value-independent, so one synthetic request prices a
//!   configuration exactly), then a dynamic program over contiguous
//!   stage cuts and per-stage widths minimizes the pipeline's bottleneck
//!   stage — the issue interval — for a target chip count.
//!
//! **Bit-exactness is by construction.**  A KN slice's conv output is
//! exactly its channel rows of the full layer's (per-filter dot products
//! are independent, and the grid plan does not depend on KN); BN + ReLU
//! and the stem pool are per-channel; concatenating the slices along the
//! channel axis therefore reproduces the full float tensor byte for
//! byte.  The one step that *couples* channels — the per-request
//! requantization scale, calibrated on the max over the **whole** layer
//! output — runs after the gather, on the gathered tensor, through the
//! same [`requantize_requests`](super::session::requantize_requests) the
//! single chip uses.  (On real hardware
//! each chip would fold its local maxima into a tiny scale all-reduce —
//! max combines exactly — quantize its slice with the global scale, and
//! gather quantized bytes; the simulator computes the identical values
//! the direct way and charges the wire for the scale exchange plus the
//! quantized payload.)  So a KN-split run is byte-identical to the
//! single-chip oracle, and register-write conservation falls out the
//! same way as for the pipeline: every filter's registers load exactly
//! once, on exactly one chip.
//!
//! The tensor-parallel session models a *protected* link (construction
//! rejects a positive `link_ber`): lossy-link studies live on the
//! layer-pipeline path ([`super::sharding::PipelineSession`] and the
//! reliability sweep), where each boundary has a single receiving stage.
//!
//! The stage machinery itself lives in the shared execution fabric
//! ([`super::exec`]): this module keeps the *planning* (KN splits, the
//! DP auto-planner, the cost probe) while the session builds its stages
//! through [`super::exec::hybrid_stage_plans`] and serves through
//! [`super::exec::run_stages`] — whose TP groups fan slice chips out
//! onto scoped threads — the same runner code the plain pipeline and
//! the threaded server execute.

use std::collections::HashMap;

use crate::coordinator::accelerator::ChipConfig;
use crate::coordinator::exec::{self, StageRunner};
use crate::coordinator::metrics::ChipMetrics;
use crate::coordinator::model::{HeadSpec, LayerSpec, ModelSpec};
use crate::coordinator::session::{
    finalize_outputs, op_wreg_footprint, ChipSession, ModelOutput, QuantActivations,
};
use crate::error::{bail, ensure, Result};
use crate::mapping::schemes::HwParams;
use crate::nn::tensor::Tensor4;
use crate::testutil::{seed_mix, Rng};

/// Ring all-gather of per-chip `chunks` (payload bytes contributed by
/// each chip): `K - 1` synchronized steps; in each step every chip
/// forwards one chunk to its neighbor, so a step is bounded by the
/// largest chunk in flight and every chunk ultimately crosses `K - 1`
/// links.  Returns `(total wire bytes, ns, hop-latency charges)`; ECC
/// wire overhead is applied per chunk via [`HwParams::wire_bytes`].
pub fn allgather_cost(chunks: &[u64], hw: &HwParams) -> (u64, f64, u64) {
    let k = chunks.len();
    if k <= 1 {
        return (0, 0.0, 0);
    }
    let wire: Vec<u64> = chunks.iter().map(|&c| hw.wire_bytes(c)).collect();
    let total: u64 = wire.iter().sum();
    let max = *wire.iter().max().expect("at least two chunks");
    let steps = (k - 1) as u64;
    let ns = steps as f64 * (hw.link_latency_ns + max as f64 / hw.link_bytes_per_ns);
    (steps * total, ns, steps)
}

/// One upstream chip feeding a `ways`-chip group: `ways` copies of the
/// payload leave the single upstream port back to back (serialized
/// bandwidth) under one hop of latency.  At `ways = 1` this is exactly
/// [`super::sharding::xfer_cost_ns`] on the wire bytes — which is what
/// makes an all-single-stage hybrid charge byte-identically to the
/// layer pipeline.
pub fn broadcast_cost(payload: u64, ways: usize, hw: &HwParams) -> (u64, f64) {
    let bytes = hw.wire_bytes(payload) * ways as u64;
    let ns = hw.link_latency_ns + bytes as f64 / hw.link_bytes_per_ns;
    (bytes, ns)
}

/// The KN split of ONE layer across `ways` chips: contiguous filter
/// ranges, near-equal by count — and therefore by register footprint,
/// which is linear in the slice width.
///
/// Splitting happens in *granule* space ([`crate::nn::ops::LayerOp::kn_granularity`]):
/// a plain conv or GEMM cuts anywhere (granule = one filter), a grouped
/// conv only at group boundaries (granule = one group's `kg` filters —
/// a group's filters share input channels no other slice would hold),
/// and a layer carrying the attention epilogue cannot be split at all
/// (the epilogue couples every QKV channel).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorPlan {
    /// Per-chip `[k0, k1)` filter ranges; contiguous, covering `0..kn`
    /// in order, sizes differing by at most one granule.
    pub slices: Vec<(usize, usize)>,
    /// Resident 2-bit weight-register entries per slice.
    pub footprints: Vec<u64>,
    /// Per-chip register capacity the split was checked against.
    pub capacity: u64,
}

impl TensorPlan {
    /// Split a layer's KN filters across `ways` chips, checking the
    /// largest slice against one chip's register capacity.
    pub fn split(ls: &LayerSpec, cfg: &ChipConfig, ways: usize) -> Result<Self> {
        ensure!(ways >= 1, "need at least one slice");
        let name = ls.op.name();
        let kn = ls.op.kn();
        let kg = ls.op.kn_granularity();
        let granules = kn / kg;
        if ways > 1 {
            ensure!(
                ls.attn.is_none(),
                "layer `{name}`: the attention epilogue couples the QKV channels; \
a KN split cannot serve it"
            );
        }
        ensure!(
            ways <= granules,
            "layer `{name}`: cannot split {granules} filter granules {ways} ways"
        );
        let need = Self::min_ways(ls, cfg)?;
        let capacity = cfg.wreg_capacity();
        ensure!(
            need <= ways,
            "layer `{name}`: a {ways}-way KN split still exceeds one chip's {capacity} \
weight-register entries; split at least {need} ways"
        );
        let planner = cfg.planner();
        // footprint is exactly linear in granules (per-group grids are
        // identical; a conv's is linear in KN), so this divides evenly
        let per_granule = op_wreg_footprint(&ls.op, &planner) / granules as u64;
        let (base, rem) = (granules / ways, granules % ways);
        let mut slices = Vec::with_capacity(ways);
        let mut footprints = Vec::with_capacity(ways);
        let mut g0 = 0usize;
        for i in 0..ways {
            let g = base + usize::from(i < rem);
            slices.push((g0 * kg, (g0 + g) * kg));
            footprints.push(g as u64 * per_granule);
            g0 += g;
        }
        debug_assert_eq!(g0 * kg, kn, "slices must partition the filters");
        debug_assert!(footprints.iter().all(|&f| f <= capacity));
        Ok(Self { slices, footprints, capacity })
    }

    /// The fewest chips this layer's registers can be split across, given
    /// one chip's capacity.  Errs when a single granule's registers
    /// exceed the chip (no KN split can help then) — or when the layer
    /// cannot be split at all (attention epilogue) and does not fit.
    pub fn min_ways(ls: &LayerSpec, cfg: &ChipConfig) -> Result<usize> {
        let planner = cfg.planner();
        let capacity = cfg.wreg_capacity();
        let name = ls.op.name();
        let total = op_wreg_footprint(&ls.op, &planner);
        if ls.attn.is_some() {
            ensure!(
                total <= capacity,
                "layer `{name}`: needs {total} weight-register entries on one chip but it \
holds {capacity}, and the attention epilogue couples the QKV channels; no KN split \
can help — shrink the layer or the batch"
            );
            return Ok(1);
        }
        let granules = (ls.op.kn() / ls.op.kn_granularity()) as u64;
        let per_granule = total / granules;
        ensure!(
            per_granule <= capacity,
            "layer `{name}`: one filter alone needs {per_granule} weight-register entries \
but a chip holds {capacity}; no KN split can help — shrink the layer or the batch"
        );
        let max_g = capacity / per_granule;
        Ok(granules.div_ceil(max_g.min(granules)) as usize)
    }

    pub fn ways(&self) -> usize {
        self.slices.len()
    }
}

/// One stage of a hybrid plan: a contiguous layer range on `ways` chips.
#[derive(Debug, Clone, PartialEq)]
pub struct HybridStagePlan {
    /// `[start, end)` layer range.
    pub range: (usize, usize),
    /// Chips this stage spans; 1 = a plain pipeline shard.
    pub ways: usize,
    /// Per-layer KN splits when `ways > 1` (aligned with `range`); empty
    /// for single-chip stages.
    pub splits: Vec<TensorPlan>,
    /// Resident register footprint per chip of this stage (chip `c`
    /// holds slice `c` of every split layer; `ways == 1` has one entry).
    pub chip_footprints: Vec<u64>,
    /// The auto-planner's simulated per-request stage latency (compute +
    /// all-gathers + entry broadcast), ns; 0.0 on manual plans.
    pub est_ns: f64,
}

/// A pipeline of tensor-parallel groups: the composition of
/// layer-boundary sharding and per-layer KN splits.
#[derive(Debug, Clone, PartialEq)]
pub struct HybridPlan {
    pub stages: Vec<HybridStagePlan>,
    /// Per-chip register capacity the plan was validated against.
    pub capacity: u64,
}

impl HybridPlan {
    /// Build and validate a plan from explicit `(start, end, ways)`
    /// stage triples: the ranges must tile the model's layers in order,
    /// and every chip's resident slice sum must fit its registers.
    pub fn manual(
        spec: &ModelSpec,
        cfg: &ChipConfig,
        stages: &[(usize, usize, usize)],
    ) -> Result<Self> {
        spec.validate()?;
        ensure!(!stages.is_empty(), "a plan needs at least one stage");
        let planner = cfg.planner();
        let capacity = cfg.wreg_capacity();
        let mut cursor = 0usize;
        let mut out = Vec::with_capacity(stages.len());
        for &(a, b, ways) in stages {
            ensure!(
                a == cursor && b > a && b <= spec.layers.len(),
                "stages must tile the layers in order: got [{a}, {b}) at layer {cursor}"
            );
            ensure!(ways >= 1, "stage [{a}, {b}): need at least one chip");
            cursor = b;
            let (splits, chip_footprints) = if ways == 1 {
                let fp: u64 = spec.layers[a..b]
                    .iter()
                    .map(|ls| op_wreg_footprint(&ls.op, &planner))
                    .sum();
                ensure!(
                    fp <= capacity,
                    "stage [{a}, {b}) needs {fp} weight-register entries on one chip but \
it holds {capacity}; cut the stage or split it across chips"
                );
                (Vec::new(), vec![fp])
            } else {
                let splits: Vec<TensorPlan> = spec.layers[a..b]
                    .iter()
                    .map(|ls| TensorPlan::split(ls, cfg, ways))
                    .collect::<Result<_>>()?;
                let mut chip = vec![0u64; ways];
                for tp in &splits {
                    for (c, &f) in tp.footprints.iter().enumerate() {
                        chip[c] += f;
                    }
                }
                for (c, &f) in chip.iter().enumerate() {
                    ensure!(
                        f <= capacity,
                        "stage [{a}, {b}): chip {c} of the {ways}-way split needs {f} \
weight-register entries but holds {capacity}; use more chips or shorter stages"
                    );
                }
                (splits, chip)
            };
            out.push(HybridStagePlan {
                range: (a, b),
                ways,
                splits,
                chip_footprints,
                est_ns: 0.0,
            });
        }
        ensure!(
            cursor == spec.layers.len(),
            "stages cover {cursor} of {} layers",
            spec.layers.len()
        );
        Ok(Self { stages: out, capacity })
    }

    /// Total chips the plan occupies.
    pub fn chips(&self) -> usize {
        self.stages.iter().map(|s| s.ways).sum()
    }

    /// The plan's estimated issue interval: its slowest stage (only
    /// meaningful on auto plans, whose `est_ns` is populated).
    pub fn est_interval_ns(&self) -> f64 {
        self.stages.iter().map(|s| s.est_ns).fold(0.0, f64::max)
    }

    /// The canonical chip numbering of the plan: stage `si` occupies
    /// `ways` consecutive fleet ordinals, in stage order — row `si` lists
    /// them, `assignment[si][c]` being the fleet chip that holds slice
    /// `c`.  This is the identity the failover layer
    /// ([`crate::coordinator::failover`]) quarantines and re-plans by.
    pub fn chip_assignment(&self) -> Vec<Vec<usize>> {
        let mut next = 0usize;
        self.stages
            .iter()
            .map(|s| {
                let row: Vec<usize> = (next..next + s.ways).collect();
                next += s.ways;
                row
            })
            .collect()
    }
}

/// Memoizing per-(layer, ways) cost probe for the auto-planner: builds a
/// throwaway resident session for the layer's **largest** slice and
/// serves one synthetic request.  Every compute path's simulated cost is
/// value-independent given the weights, so one probe prices the
/// configuration exactly; results are cached across DP transitions.
struct CostProbe<'a> {
    cfg: &'a ChipConfig,
    spec: &'a ModelSpec,
    hw: &'a HwParams,
    cache: HashMap<(usize, usize), Option<f64>>,
}

impl CostProbe<'_> {
    fn layer_cost(&mut self, li: usize, ways: usize) -> Option<f64> {
        if let Some(&c) = self.cache.get(&(li, ways)) {
            return c;
        }
        let v = self.probe(li, ways);
        self.cache.insert((li, ways), v);
        v
    }

    /// Per-chip latency of layer `li` under a `ways`-way split: slice 0's
    /// compute (the largest slice bounds the group) plus, when split, the
    /// post-layer scale exchange and payload all-gather.
    fn probe(&mut self, li: usize, ways: usize) -> Option<f64> {
        let ls = &self.spec.layers[li];
        let tp = TensorPlan::split(ls, self.cfg, ways).ok()?;
        let (k0, k1) = tp.slices[0];
        let slice = if ways == 1 { ls.clone() } else { ls.slice_kn(k0, k1).ok()? };
        let sub = ModelSpec {
            name: format!("probe:{}:{ways}w", ls.op.name()),
            layers: vec![slice],
            head: None,
        };
        let mut sess = ChipSession::new(*self.cfg, sub).ok()?;
        let (n, c, h, w) = ls.op.in_geometry();
        let mut q = Tensor4::zeros(n, c, h, w);
        q.fill_random_ints(&mut Rng::new(seed_mix(0x9906, li as u64)), 0, 256);
        let act = QuantActivations { q, scales: vec![255.0] };
        let (_, m) = sess.run_quantized(act).ok()?;
        let mut ns = m.latency_ns;
        if ways > 1 {
            // attention layers never reach here: split() rejects them at
            // ways > 1, so kn below is the layer's raw channel count
            let (_, kn, mut oh, mut ow) = ls.op.out_geometry();
            if ls.pool_after {
                oh = (oh / 2).max(1);
                ow = (ow / 2).max(1);
            }
            let batch = ls.op.batch();
            // Serving requantizes the FULL gathered tensor, but the probe
            // run above only charged the slice's share: add the missing
            // channels' requantization time (exact — the DPU pass is
            // linear in elements), so w > 1 stage costs stay comparable
            // with w = 1 and the DP never picks a split on phantom
            // savings.
            let missing = (kn - (k1 - k0)) * batch * oh * ow;
            if missing > 0 {
                ns += crate::coordinator::dpu::Dpu
                    .requantize(&vec![0.0; missing], 1.0)
                    .latency_ns;
            }
            let chunks: Vec<u64> = tp
                .slices
                .iter()
                .map(|&(a, b)| ((b - a) * batch * oh * ow) as u64)
                .collect();
            ns += allgather_cost(&vec![4u64; ways], self.hw).1; // scale exchange
            ns += allgather_cost(&chunks, self.hw).1; // quantized partials
        }
        Some(ns)
    }
}

/// Latency (and feasibility) of running layers `[i, j)` as one stage on
/// `w` chips; `None` when some chip cannot hold its slices.  Non-head
/// stages additionally pay the broadcast of their input tensor from the
/// previous stage's chip.
fn stage_cost(probe: &mut CostProbe, i: usize, j: usize, w: usize, first: bool) -> Option<f64> {
    let planner = probe.cfg.planner();
    let capacity = probe.cfg.wreg_capacity();
    // chip 0 holds the largest slice of every layer, so its sum is the
    // per-chip footprint bound (and equals the plain footprint at w = 1)
    let mut fp = 0u64;
    for ls in &probe.spec.layers[i..j] {
        if w == 1 {
            fp += op_wreg_footprint(&ls.op, &planner);
        } else {
            fp += TensorPlan::split(ls, probe.cfg, w).ok()?.footprints[0];
        }
    }
    if fp > capacity {
        return None;
    }
    let mut ns = 0.0;
    for li in i..j {
        ns += probe.layer_cost(li, w)?;
    }
    if !first {
        let (n, c, h, wd) = probe.spec.layers[i].op.in_geometry();
        let payload = (n * c * h * wd) as u64 + 4;
        ns += broadcast_cost(payload, w, probe.hw).1;
    }
    Some(ns)
}

/// The latency-balanced auto-planner: pick the cheapest valid
/// (shards x kn-splits) configuration for a target chip count.
///
/// Per-layer latencies are *simulated* (see [`CostProbe`]), then a
/// dynamic program over contiguous stage cuts and per-stage split widths
/// minimizes the bottleneck stage — which bounds the pipeline's issue
/// interval — using **at most** `chips` chips.  Oversized layers are
/// forced to the split widths that fit; everything else is free for the
/// DP to trade between deeper pipelining and wider splits.
pub fn plan_auto(
    cfg: &ChipConfig,
    spec: &ModelSpec,
    chips: usize,
    hw: &HwParams,
) -> Result<HybridPlan> {
    spec.validate()?;
    ensure!(chips >= 1, "need at least one chip");
    let l = spec.layers.len();
    // surface the hopeless case (a single granule too big, or an
    // unsplittable attention layer over capacity) as its own error
    for ls in &spec.layers {
        TensorPlan::min_ways(ls, cfg)?;
    }
    let mut probe = CostProbe { cfg, spec, hw, cache: HashMap::new() };

    #[derive(Clone, Copy)]
    struct Step {
        cost: f64,
        next: usize,
        ways: usize,
    }
    // dp[i][c]: best bottleneck for layers i.. with c chips left
    let mut dp: Vec<Vec<Option<Step>>> = vec![vec![None; chips + 1]; l + 1];
    for slot in dp[l].iter_mut() {
        *slot = Some(Step { cost: 0.0, next: l, ways: 0 });
    }
    for i in (0..l).rev() {
        for c in 1..=chips {
            let mut best: Option<Step> = None;
            for j in (i + 1)..=l {
                for w in 1..=c {
                    let Some(rest) = dp[j][c - w] else { continue };
                    let Some(stage_ns) = stage_cost(&mut probe, i, j, w, i == 0) else {
                        continue;
                    };
                    let cand = stage_ns.max(rest.cost);
                    let better = match best {
                        None => true,
                        Some(b) => cand < b.cost || (cand == b.cost && w < b.ways),
                    };
                    if better {
                        best = Some(Step { cost: cand, next: j, ways: w });
                    }
                }
            }
            dp[i][c] = best;
        }
    }
    if dp[0][chips].is_none() {
        bail!(
            "no (shards x kn-splits) configuration of `{}` fits {chips} chip(s) of {} \
weight-register entries; add chips",
            spec.name,
            cfg.wreg_capacity()
        );
    }
    let mut triples = Vec::new();
    let (mut i, mut c) = (0usize, chips);
    while i < l {
        let s = dp[i][c].expect("dp reconstruction follows a feasible path");
        triples.push((i, s.next, s.ways));
        i = s.next;
        c -= s.ways;
    }
    let mut plan = HybridPlan::manual(spec, cfg, &triples)?;
    for st in &mut plan.stages {
        let (a, b) = st.range;
        st.est_ns = stage_cost(&mut probe, a, b, st.ways, a == 0)
            .expect("chosen stages were feasible in the DP");
    }
    Ok(plan)
}

/// Per-layer serving profile for planning and reporting: each layer
/// priced by the simulator at its minimum feasible KN split width
/// (width 1 — the whole layer on one chip — whenever it fits).  Returns
/// `(min_ways, per-chip latency_ns)` per layer; the latencies feed
/// [`super::sharding::ShardPlan::partition_weighted`] as the
/// latency-balanced pipeline objective.
pub fn profile_layers(
    cfg: &ChipConfig,
    spec: &ModelSpec,
    hw: &HwParams,
) -> Result<Vec<(usize, f64)>> {
    spec.validate()?;
    let mut probe = CostProbe { cfg, spec, hw, cache: HashMap::new() };
    let mut out = Vec::with_capacity(spec.layers.len());
    for (li, ls) in spec.layers.iter().enumerate() {
        let ways = TensorPlan::min_ways(ls, cfg)?;
        let Some(ns) = probe.layer_cost(li, ways) else {
            bail!("layer `{}` cannot be profiled at {ways} ways", ls.op.name());
        };
        out.push((ways, ns));
    }
    Ok(out)
}

/// The per-request result of a hybrid run (possibly micro-batched).
#[derive(Debug, Clone)]
pub struct HybridOutput {
    /// Per-request outputs in submission order; fused requests share the
    /// run's metrics (which aggregate every stage plus all link legs).
    pub outs: Vec<ModelOutput>,
    /// Per-stage metrics: compute plus the stage's internal all-gathers,
    /// without the inter-stage boundary legs.
    pub stage_metrics: Vec<ChipMetrics>,
    /// Inter-stage boundary legs, ns (`stages - 1` entries).
    pub boundary_legs_ns: Vec<f64>,
}

impl HybridOutput {
    /// Steady-state issue interval
    /// ([`super::sharding::staged_issue_interval_ns`]): the slowest
    /// stage plus its incoming boundary leg bounds how often a new
    /// request can enter.  For the true single-chip cost per request,
    /// serve the same input through a capacity-unlimited oracle: a TP
    /// stage's latency is its slowest *slice* plus gather time, which no
    /// single chip pays, so summing stages does not reconstruct it.
    pub fn issue_interval_ns(&self) -> f64 {
        crate::coordinator::sharding::staged_issue_interval_ns(
            &self.stage_metrics,
            &self.boundary_legs_ns,
        )
    }
}

/// A model resident across a hybrid plan's chips, served as a pipeline
/// of tensor-parallel groups.  Construction loads every slice's
/// registers once; serving streams activations against the resident
/// state, byte-identical to the single-chip oracle.
pub struct TensorParallelSession {
    cfg: ChipConfig,
    plan: HybridPlan,
    stages: Vec<StageRunner>,
    head: Option<HeadSpec>,
    hw: HwParams,
    input_geometry: (usize, usize, usize, usize),
    served: u64,
}

impl TensorParallelSession {
    /// Load `spec` across the plan's chips.  The tensor-parallel link is
    /// modeled as protected: a positive `hw.link_ber` is rejected here
    /// (use [`super::sharding::PipelineSession`] for lossy-link studies).
    pub fn new(cfg: ChipConfig, spec: ModelSpec, plan: HybridPlan, hw: HwParams) -> Result<Self> {
        ensure!(
            hw.link_bytes_per_ns > 0.0 && hw.link_latency_ns >= 0.0,
            "inter-chip link needs positive bandwidth and non-negative latency"
        );
        ensure!(
            hw.link_ber == 0.0,
            "the tensor-parallel session models a protected link; lossy links live on \
the layer-pipeline path (PipelineSession / the reliability sweep)"
        );
        spec.validate()?;
        let stages = exec::build_stages(cfg, exec::hybrid_stage_plans(&spec, &plan, cfg.fault)?)?;
        Ok(Self {
            cfg,
            plan,
            stages,
            head: spec.head.clone(),
            hw,
            input_geometry: spec.input_geometry(),
            served: 0,
        })
    }

    /// Auto-plan for `chips` chips ([`plan_auto`]) and load.
    pub fn auto(cfg: ChipConfig, spec: ModelSpec, chips: usize, hw: HwParams) -> Result<Self> {
        let plan = plan_auto(&cfg, &spec, chips, &hw)?;
        Self::new(cfg, spec, plan, hw)
    }

    pub fn plan(&self) -> &HybridPlan {
        &self.plan
    }

    /// The link parameters transfers are charged against.
    pub fn hw(&self) -> &HwParams {
        &self.hw
    }

    /// The input geometry requests must match.
    pub fn input_geometry(&self) -> (usize, usize, usize, usize) {
        self.input_geometry
    }

    /// Requests served so far.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// One-time loading metrics per stage, each entry summing the
    /// stage's chips (a `ways = 1` stage has one chip).
    pub fn stage_loadings(&self) -> Vec<ChipMetrics> {
        self.stages.iter().map(StageRunner::loading).collect()
    }

    /// Loading totals across every chip.  `weight_reg_writes` equals the
    /// unsharded model's: every filter's registers load exactly once,
    /// on exactly one chip — conservation across slices.
    pub fn loading_total(&self) -> ChipMetrics {
        let mut total = ChipMetrics::default();
        for m in self.stage_loadings() {
            total.add(&m);
        }
        total
    }

    /// Serve one request; see [`Self::infer_many`].
    pub fn infer(&mut self, x: &Tensor4) -> Result<HybridOutput> {
        self.infer_many(&[x])
    }

    /// Fuse several same-shape requests into one run through the hybrid
    /// pipeline.  Outputs are bit-identical to the single-chip oracle
    /// (and re-split exactly), every boundary broadcast and every ring
    /// all-gather is charged once per fused run, and the resident
    /// registers are never rewritten.
    pub fn infer_many(&mut self, xs: &[&Tensor4]) -> Result<HybridOutput> {
        ensure!(!xs.is_empty(), "micro-batch needs at least one request");
        let k = xs.len();
        if k > 1 {
            exec::ensure_fused_capacity(&self.stages, &self.cfg, k)?;
        }
        let (act, metrics) = self.stages[0].entry().quantize_entry(xs)?;
        let run = exec::run_stages(&mut self.stages, act, metrics, &self.hw, &mut [])?;
        self.served += k as u64;
        let outs = finalize_outputs(self.head.as_ref(), run.act, run.metrics);
        Ok(HybridOutput {
            outs,
            stage_metrics: run.stage_metrics,
            boundary_legs_ns: run.boundary_legs_ns,
        })
    }
}

/// Concatenate per-slice partial feature maps along the channel axis:
/// the inverse of the KN split, byte-exact.
pub(crate) fn concat_channels(parts: &[Tensor4]) -> Tensor4 {
    let (n, h, w) = (parts[0].n, parts[0].h, parts[0].w);
    debug_assert!(parts.iter().all(|p| p.n == n && p.h == h && p.w == w));
    let c: usize = parts.iter().map(|p| p.c).sum();
    let hw = h * w;
    let mut out = Tensor4::zeros(n, c, h, w);
    for ni in 0..n {
        let mut c0 = 0usize;
        for p in parts {
            let src = &p.data[ni * p.c * hw..(ni + 1) * p.c * hw];
            let dst0 = (ni * c + c0) * hw;
            out.data[dst0..dst0 + p.c * hw].copy_from_slice(src);
            c0 += p.c;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::LoadedModel;
    use crate::coordinator::sharding::{xfer_cost_ns, PipelineSession, ShardPlan};
    use crate::nn::ops::{GroupedConvLayer, LayerOp};
    use crate::nn::resnet::ConvLayer;
    use crate::nn::workloads::WorkloadLayer;
    use crate::testutil::prop_check;

    /// Three chained layers whose KN widths (8, 6, 4) admit 2/3/4-way
    /// splits.  Footprints on a 256-column planner: [216, 432, 216].
    fn wide_kn(seed: u64) -> ModelSpec {
        let geo = vec![
            ConvLayer { name: "k1", n: 1, c: 3, h: 8, w: 8, kn: 8, kh: 3, kw: 3, stride: 1, pad: 1 },
            ConvLayer { name: "k2", n: 1, c: 8, h: 8, w: 8, kn: 6, kh: 3, kw: 3, stride: 2, pad: 1 },
            ConvLayer { name: "k3", n: 1, c: 6, h: 4, w: 4, kn: 4, kh: 3, kw: 3, stride: 1, pad: 1 },
        ];
        ModelSpec::synthetic("widekn", &geo, false, 0.5, seed, Some(5))
    }

    /// A chip generation whose 300-entry register files reject `wide_kn`
    /// outright: layer k2 alone needs 432 entries.
    fn small_chip() -> ChipConfig {
        let mut cfg = ChipConfig::fat();
        cfg.cmas = 3;
        cfg.wreg_entries_per_cma = 100;
        cfg
    }

    #[test]
    fn chip_assignment_numbers_stage_chips_consecutively() {
        let cfg = ChipConfig::fat();
        let spec = wide_kn(0xA551);
        let plan = HybridPlan::manual(&spec, &cfg, &[(0, 1, 1), (1, 2, 2), (2, 3, 1)])
            .expect("mixed plan");
        assert_eq!(plan.chip_assignment(), vec![vec![0], vec![1, 2], vec![3]]);
        assert_eq!(
            plan.chip_assignment().iter().map(Vec::len).sum::<usize>(),
            plan.chips(),
            "every chip of the plan appears exactly once"
        );
    }

    #[test]
    fn tensor_plan_slices_partition_kn_exactly() {
        // ISSUE 5 satellite: property tests for the KN split.
        prop_check(
            "KN slices are contiguous, covering, balanced, and within capacity",
            20,
            0x7E50,
            |rng| {
                let c = rng.range(1, 9);
                let kn = rng.range(1, 20);
                let h = rng.range(4, 12);
                ConvLayer { name: "p", n: 1, c, h, w: h, kn, kh: 3, kw: 3, stride: 1, pad: 1 }
            },
            |layer| {
                let cfg = ChipConfig::fat();
                let planner = cfg.planner();
                let per_filter =
                    layer.j_dim() as u64 * planner.col_tiles(layer) as u64;
                let spec = ModelSpec::synthetic("p", &[*layer], false, 0.5, 7, None);
                let ls = &spec.layers[0];
                for ways in 1..=layer.kn {
                    let tp = TensorPlan::split(ls, &cfg, ways)
                        .map_err(|e| format!("{ways} ways: {e:#}"))?;
                    if tp.ways() != ways {
                        return Err(format!("wanted {ways} slices, got {:?}", tp.slices));
                    }
                    // contiguous cover of 0..kn, in order
                    if tp.slices[0].0 != 0 || tp.slices[ways - 1].1 != layer.kn {
                        return Err(format!("slices do not span KN: {:?}", tp.slices));
                    }
                    for w in tp.slices.windows(2) {
                        if w[0].1 != w[1].0 {
                            return Err(format!("gap/overlap: {:?}", tp.slices));
                        }
                    }
                    let sizes: Vec<usize> =
                        tp.slices.iter().map(|&(a, b)| b - a).collect();
                    if sizes.iter().any(|&s| s == 0) {
                        return Err(format!("empty slice in {:?}", tp.slices));
                    }
                    let (lo, hi) =
                        (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                    if hi - lo > 1 {
                        return Err(format!("unbalanced slices {sizes:?}"));
                    }
                    for (&s, &fp) in sizes.iter().zip(&tp.footprints) {
                        if fp != s as u64 * per_filter {
                            return Err(format!(
                                "footprint {fp} != {s} x {per_filter}"
                            ));
                        }
                        if fp > tp.capacity {
                            return Err(format!("slice footprint {fp} over capacity"));
                        }
                    }
                }
                // min_ways is feasible and minimal under a tight capacity
                let m = 1 + (layer.kn as u64).min(3);
                let mut tight = cfg;
                tight.cmas = 1;
                tight.wreg_entries_per_cma = (per_filter * m) as usize;
                let need = TensorPlan::min_ways(ls, &tight)
                    .map_err(|e| format!("min_ways: {e:#}"))?;
                if TensorPlan::split(ls, &tight, need).is_err() {
                    return Err(format!("min_ways {need} must be feasible"));
                }
                if need > 1 && TensorPlan::split(ls, &tight, need - 1).is_ok() {
                    return Err(format!("{} ways should not fit", need - 1));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn min_ways_errors_when_one_filter_cannot_fit() {
        let wspec = wide_kn(1);
        let ls = &wspec.layers[1]; // k2: 72 entries per filter
        let mut cfg = ChipConfig::fat();
        cfg.cmas = 1;
        cfg.wreg_entries_per_cma = 71;
        let err = TensorPlan::min_ways(ls, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("one filter alone"), "{err:#}");
        assert!(TensorPlan::split(ls, &cfg, 6).is_err());
        // and plan_auto surfaces the same hopeless case
        let spec = wide_kn(1);
        assert!(plan_auto(&cfg, &spec, 8, &HwParams::default()).is_err());
    }

    #[test]
    fn kn_split_matches_the_single_chip_oracle_at_2_3_4_ways() {
        // tentpole acceptance: whole-model KN splits are byte-identical
        // to the single-chip oracle, conserve register writes across the
        // slices, and charge the all-gather on every split layer.
        let cfg = ChipConfig::fat();
        let hw = HwParams::default();
        let spec = wide_kn(11);
        let mut oracle = ChipSession::new(cfg, spec.clone()).unwrap();
        let mut rng = Rng::new(0x7E51);
        let xs: Vec<Tensor4> = (0..2).map(|_| spec.random_input(&mut rng)).collect();
        let wants: Vec<ModelOutput> = xs.iter().map(|x| oracle.infer(x).unwrap()).collect();

        for ways in [2usize, 3, 4] {
            let plan = HybridPlan::manual(&spec, &cfg, &[(0, 3, ways)]).unwrap();
            assert_eq!(plan.chips(), ways);
            let mut tp = TensorParallelSession::new(cfg, spec.clone(), plan, hw).unwrap();

            // register-write conservation: every filter loads exactly
            // once, on exactly one chip
            assert_eq!(
                tp.loading_total().weight_reg_writes,
                oracle.loading().weight_reg_writes,
                "{ways}-way split must conserve register writes"
            );

            for (x, want) in xs.iter().zip(&wants) {
                let ho = tp.infer(x).unwrap();
                let out = &ho.outs[0];
                assert_eq!(
                    out.features.data, want.features.data,
                    "{ways}-way KN split must match the oracle byte for byte"
                );
                assert_eq!(out.logits, want.logits, "{ways}-way logits must match");
                // all-gather legs: 2 ring gathers (scales + payload) per
                // split layer, ways-1 hops each, no stage boundaries
                assert_eq!(out.metrics.xfer_legs, 3 * 2 * (ways as u64 - 1));
                assert!(out.metrics.xfer_bytes > 0 && out.metrics.xfer_ns > 0.0);
                assert_eq!(out.metrics.weight_reg_writes, 0, "weights stay resident");
                assert!(ho.boundary_legs_ns.is_empty(), "one stage, no boundaries");
                // the oracle pays no transfer
                assert_eq!(want.metrics.xfer_ns, 0.0);
                assert!(out.metrics.latency_ns > want.metrics.latency_ns);
            }
        }
    }

    #[test]
    fn oversized_layer_rejected_everywhere_else_serves_under_a_kn_split() {
        // THE acceptance scenario: a model whose largest layer exceeds
        // one chip's registers is rejected by LoadedModel::load AND by
        // layer-boundary sharding, yet serves end-to-end bit-exactly
        // under the hybrid auto-planner.
        let small = small_chip(); // 300-entry chips; k2 needs 432
        let spec = wide_kn(13);
        let load_err = LoadedModel::load(small, spec.clone()).unwrap_err();
        assert!(format!("{load_err:#}").contains("shard"), "{load_err:#}");
        let shard_err = ShardPlan::partition(&spec, &small, 3).unwrap_err();
        assert!(
            format!("{shard_err:#}").contains("cannot help"),
            "layer-boundary sharding must report the oversized layer: {shard_err:#}"
        );
        assert!(ShardPlan::min_shards(&spec, &small).is_err());
        assert_eq!(TensorPlan::min_ways(&spec.layers[1], &small).unwrap(), 2);

        // too few chips: no hybrid exists (hand-checked: every <=3-chip
        // stage assignment puts >300 entries on some chip)
        let hw = HwParams::default();
        assert!(plan_auto(&small, &spec, 3, &hw).is_err());

        // 4 chips: the auto-planner finds a valid hybrid, k2 split >= 2
        let plan = plan_auto(&small, &spec, 4, &hw).unwrap();
        assert!(plan.chips() <= 4);
        assert!(plan.est_interval_ns() > 0.0);
        for st in &plan.stages {
            for &fp in &st.chip_footprints {
                assert!(fp <= small.wreg_capacity(), "plan must respect capacity");
            }
            if (st.range.0..st.range.1).contains(&1) {
                assert!(st.ways >= 2, "the oversized layer k2 must be split");
            }
        }

        // byte-identical to a big-chip oracle with the same array
        // geometry (capacity is only a gate, never a value change)
        let mut big = small;
        big.wreg_entries_per_cma = 8192;
        let mut oracle = ChipSession::new(big, spec.clone()).unwrap();
        let mut tp = TensorParallelSession::new(small, spec.clone(), plan, hw).unwrap();
        assert_eq!(
            tp.loading_total().weight_reg_writes,
            oracle.loading().weight_reg_writes
        );
        let mut rng = Rng::new(0x7E52);
        for i in 0..2 {
            let x = spec.random_input(&mut rng);
            let want = oracle.infer(&x).unwrap();
            let ho = tp.infer(&x).unwrap();
            assert_eq!(
                ho.outs[0].features.data, want.features.data,
                "request {i}: rejected-model serving must be bit-exact under the split"
            );
            assert_eq!(ho.outs[0].logits, want.logits);
            assert!(ho.outs[0].metrics.xfer_ns > 0.0, "the gathers are charged");
        }
    }

    #[test]
    fn all_single_stage_hybrid_is_byte_identical_to_the_pipeline() {
        // composition sanity: with every stage at ways = 1 the hybrid
        // session IS the layer pipeline — outputs AND full metrics.
        let cfg = ChipConfig::fat();
        let hw = HwParams::default();
        let spec = wide_kn(17);
        let shard = ShardPlan::partition(&spec, &cfg, 2).unwrap();
        assert_eq!(shard.ranges, vec![(0, 2), (2, 3)]);
        let mut pipe = PipelineSession::new(cfg, spec.clone(), 2, hw).unwrap();
        let plan = HybridPlan::manual(&spec, &cfg, &[(0, 2, 1), (2, 3, 1)]).unwrap();
        let mut hybrid = TensorParallelSession::new(cfg, spec.clone(), plan, hw).unwrap();
        assert_eq!(
            hybrid.loading_total().weight_reg_writes,
            pipe.loading_total().weight_reg_writes
        );
        let mut rng = Rng::new(0x7E53);
        for _ in 0..2 {
            let x = spec.random_input(&mut rng);
            let want = pipe.infer(&x).unwrap();
            let got = hybrid.infer(&x).unwrap();
            assert_eq!(got.outs[0].features.data, want.out.features.data);
            assert_eq!(got.outs[0].logits, want.out.logits);
            assert_eq!(got.outs[0].metrics, want.out.metrics, "full metrics must match");
            assert_eq!(got.stage_metrics, want.stage_metrics);
            assert_eq!(got.boundary_legs_ns, want.xfer_legs_ns);
            assert_eq!(got.issue_interval_ns(), want.issue_interval_ns());
        }
    }

    #[test]
    fn fused_tp_requests_resplit_bit_identically_and_amortize_gathers() {
        // micro-batching through a tensor-parallel group: outputs re-split
        // exactly, and the ring's hop latencies are paid once per fused
        // run instead of once per request.
        let cfg = ChipConfig::fat();
        let hw = HwParams::default();
        let spec = wide_kn(19);
        let plan = HybridPlan::manual(&spec, &cfg, &[(0, 3, 2)]).unwrap();
        let mut solo = TensorParallelSession::new(
            cfg, spec.clone(), plan.clone(), hw,
        )
        .unwrap();
        let mut fused = TensorParallelSession::new(cfg, spec.clone(), plan, hw).unwrap();
        let mut rng = Rng::new(0x7E54);
        let xs: Vec<Tensor4> = (0..3).map(|_| spec.random_input(&mut rng)).collect();

        let wants: Vec<ModelOutput> =
            xs.iter().map(|x| solo.infer(x).unwrap().outs.remove(0)).collect();
        let refs: Vec<&Tensor4> = xs.iter().collect();
        let ho = fused.infer_many(&refs).unwrap();
        assert_eq!(ho.outs.len(), 3);
        assert_eq!(fused.served(), 3);
        for (g, w) in ho.outs.iter().zip(&wants) {
            assert_eq!(g.features.data, w.features.data, "fused TP must re-split exactly");
            assert_eq!(g.logits, w.logits);
        }
        // hop charges: 6 ring steps for the fused run vs 18 for 3 solos
        let solo_legs: u64 = wants.iter().map(|w| w.metrics.xfer_legs).sum();
        assert_eq!(ho.outs[0].metrics.xfer_legs, 6);
        assert_eq!(solo_legs, 18);
        let solo_xfer: f64 = wants.iter().map(|w| w.metrics.xfer_ns).sum();
        assert!(
            ho.outs[0].metrics.xfer_ns < solo_xfer,
            "fused gathers {} ns must undercut {} ns of solo gathers",
            ho.outs[0].metrics.xfer_ns,
            solo_xfer
        );
    }

    #[test]
    fn auto_planner_uses_extra_chips_only_when_they_help() {
        let cfg = ChipConfig::fat();
        let hw = HwParams::default();
        let spec = wide_kn(23);
        let p1 = plan_auto(&cfg, &spec, 1, &hw).unwrap();
        assert_eq!(p1.chips(), 1);
        assert_eq!(p1.stages.len(), 1);
        assert_eq!(p1.stages[0].ways, 1);
        let p3 = plan_auto(&cfg, &spec, 3, &hw).unwrap();
        assert!(p3.chips() <= 3);
        // the DP always considers the 1-chip plan, so more chips can
        // never make the bottleneck worse
        assert!(p3.est_interval_ns() <= p1.est_interval_ns() + 1e-9);
        // the plan is servable and exact
        let mut oracle = ChipSession::new(cfg, spec.clone()).unwrap();
        let mut sess = TensorParallelSession::new(cfg, spec.clone(), p3, hw).unwrap();
        let x = spec.random_input(&mut Rng::new(0x7E55));
        let want = oracle.infer(&x).unwrap();
        let got = sess.infer(&x).unwrap();
        assert_eq!(got.outs[0].features.data, want.features.data);
        assert_eq!(got.outs[0].logits, want.logits);
    }

    #[test]
    fn hybrid_plan_manual_validates_tiling_and_capacity() {
        let cfg = ChipConfig::fat();
        let spec = wide_kn(29);
        // gaps, overlaps, short cover, zero ways
        assert!(HybridPlan::manual(&spec, &cfg, &[(0, 2, 1)]).is_err());
        assert!(HybridPlan::manual(&spec, &cfg, &[(0, 2, 1), (1, 3, 1)]).is_err());
        assert!(HybridPlan::manual(&spec, &cfg, &[(1, 3, 1)]).is_err());
        assert!(HybridPlan::manual(&spec, &cfg, &[(0, 3, 0)]).is_err());
        // splitting wider than KN is rejected
        assert!(HybridPlan::manual(&spec, &cfg, &[(0, 3, 5)]).is_err());
        // per-chip capacity on a multi-layer TP stage
        let small = small_chip();
        let err = HybridPlan::manual(&spec, &small, &[(0, 3, 2)]).unwrap_err();
        assert!(format!("{err:#}").contains("chip 0"), "{err:#}");
        assert!(HybridPlan::manual(&spec, &small, &[(0, 1, 1), (1, 2, 2), (2, 3, 1)]).is_ok());
    }

    #[test]
    fn tensor_parallel_session_rejects_a_lossy_link() {
        let cfg = ChipConfig::fat();
        let spec = wide_kn(31);
        let plan = HybridPlan::manual(&spec, &cfg, &[(0, 3, 2)]).unwrap();
        let hw = HwParams { link_ber: 0.01, ..HwParams::default() };
        let err = TensorParallelSession::new(cfg, spec, plan, hw).unwrap_err();
        assert!(format!("{err:#}").contains("protected link"), "{err:#}");
    }

    #[test]
    fn gather_and_broadcast_cost_model() {
        let hw = HwParams::default();
        // a single chip gathers nothing
        assert_eq!(allgather_cost(&[100], &hw), (0, 0.0, 0));
        // 2-chip ring: one step bounded by the larger chunk
        let (bytes, ns, legs) = allgather_cost(&[100, 60], &hw);
        assert_eq!(bytes, 160);
        assert_eq!(legs, 1);
        assert!((ns - (hw.link_latency_ns + 100.0 / hw.link_bytes_per_ns)).abs() < 1e-12);
        // 4-chip ring: 3 steps, every chunk crosses 3 links
        let (bytes, ns, legs) = allgather_cost(&[50, 50, 50, 50], &hw);
        assert_eq!(bytes, 3 * 200);
        assert_eq!(legs, 3);
        assert!((ns - 3.0 * (hw.link_latency_ns + 50.0 / hw.link_bytes_per_ns)).abs() < 1e-12);
        // broadcast to one receiver IS the pipeline leg
        let (b1, n1) = broadcast_cost(4096, 1, &hw);
        assert_eq!(b1, 4096);
        assert_eq!(n1, xfer_cost_ns(4096, &hw));
        // ... and to three receivers, three serialized copies
        let (b3, n3) = broadcast_cost(4096, 3, &hw);
        assert_eq!(b3, 3 * 4096);
        assert!(n3 > n1);
        // SECDED wire overhead reaches the gather model
        let ecc = HwParams { link_ecc: true, ..HwParams::default() };
        let (eb, ens, _) = allgather_cost(&[64, 64], &ecc);
        assert_eq!(eb, 2 * 72);
        assert!(ens > allgather_cost(&[64, 64], &hw).1);
    }

    #[test]
    fn concat_channels_inverts_the_split() {
        let mut rng = Rng::new(0x7E56);
        let mut full = Tensor4::zeros(2, 5, 3, 3);
        full.fill_random_ints(&mut rng, 0, 100);
        // split channels [0,2) and [2,5), then re-concatenate
        let hw = 9usize;
        let take = |c0: usize, c1: usize| {
            let mut t = Tensor4::zeros(2, c1 - c0, 3, 3);
            for n in 0..2 {
                for (ci, c) in (c0..c1).enumerate() {
                    for i in 0..hw {
                        t.data[(n * (c1 - c0) + ci) * hw + i] =
                            full.data[(n * 5 + c) * hw + i];
                    }
                }
            }
            t
        };
        let back = concat_channels(&[take(0, 2), take(2, 5)]);
        assert_eq!(back.data, full.data);
        assert_eq!(back.shape(), full.shape());
    }

    #[test]
    fn grouped_split_cuts_only_group_boundaries() {
        // 4 groups x kg = 3 filters: splits happen in granule space, so
        // slice edges always land on multiples of kg, and a split wider
        // than the group count is refused even though kn would allow it.
        let cfg = ChipConfig::fat();
        let g = GroupedConvLayer {
            name: "g4",
            n: 1,
            h: 6,
            w: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            groups: 4,
            cg: 2,
            kg: 3,
            c_offset: 0,
            c_in: 8,
        };
        let wl = [WorkloadLayer::plain(LayerOp::GroupedConv(g))];
        let spec = ModelSpec::synthetic_ops("g4", &wl, 0.5, 0x7E60, None);
        let ls = &spec.layers[0];
        let tp = TensorPlan::split(ls, &cfg, 3).unwrap();
        assert_eq!(tp.slices, vec![(0, 6), (6, 9), (9, 12)], "granule-aligned slices");
        assert_eq!(tp.footprints[0], 2 * tp.footprints[1], "footprint linear in granules");
        let err = TensorPlan::split(ls, &cfg, 5).unwrap_err();
        assert!(format!("{err:#}").contains("granules"), "{err:#}");

        // and a 2-way grouped split serves byte-identically to the oracle
        let mut oracle = ChipSession::new(cfg, spec.clone()).unwrap();
        let plan = HybridPlan::manual(&spec, &cfg, &[(0, 1, 2)]).unwrap();
        assert_eq!(plan.stages[0].splits[0].slices, vec![(0, 6), (6, 12)]);
        let mut tp_sess =
            TensorParallelSession::new(cfg, spec.clone(), plan, HwParams::default()).unwrap();
        assert_eq!(
            tp_sess.loading_total().weight_reg_writes,
            oracle.loading().weight_reg_writes,
            "grouped split must conserve register writes"
        );
        let x = spec.random_input(&mut Rng::new(0x7E61));
        let want = oracle.infer(&x).unwrap();
        let got = tp_sess.infer(&x).unwrap();
        assert_eq!(got.outs[0].features.data, want.features.data, "grouped split == oracle");
    }

    #[test]
    fn attention_layer_refuses_multi_way_splits() {
        let cfg = ChipConfig::fat();
        let spec = ModelSpec::synthetic_transformer(6, 8, 2, 2, 0.5, 0x7E62);
        let qkv = &spec.layers[0];
        assert!(qkv.attn.is_some());
        // whole-layer "split" (ways = 1) stays legal — the probe and the
        // DP rely on it — but any real cut is refused
        assert!(TensorPlan::split(qkv, &cfg, 1).is_ok());
        let err = TensorPlan::split(qkv, &cfg, 2).unwrap_err();
        assert!(format!("{err:#}").contains("attention"), "{err:#}");
        assert_eq!(TensorPlan::min_ways(qkv, &cfg).unwrap(), 1);
        // an attention layer over capacity is hopeless, not splittable
        let mut tiny = cfg;
        tiny.cmas = 1;
        tiny.wreg_entries_per_cma = 8;
        let err = TensorPlan::min_ways(qkv, &tiny).unwrap_err();
        assert!(format!("{err:#}").contains("no KN split can help"), "{err:#}");
    }

    #[test]
    fn workload_models_serve_byte_identically_under_auto_plans() {
        // tentpole acceptance at the TP layer: both new compute shapes go
        // through plan_auto and serve byte-identically to the single-chip
        // oracle, conserving register writes.
        let cfg = ChipConfig::fat();
        let hw = HwParams::default();
        let specs = [
            ModelSpec::synthetic_transformer(6, 8, 2, 2, 0.5, 0x7E63),
            ModelSpec::synthetic_mobilenet(1, 16, 6, 0.5, 0x7E64, 4),
        ];
        for spec in specs {
            let mut oracle = ChipSession::new(cfg, spec.clone()).unwrap();
            let plan = plan_auto(&cfg, &spec, 3, &hw).unwrap();
            assert!(plan.chips() <= 3, "{}", spec.name);
            let mut tp = TensorParallelSession::new(cfg, spec.clone(), plan, hw).unwrap();
            assert_eq!(
                tp.loading_total().weight_reg_writes,
                oracle.loading().weight_reg_writes,
                "{}: conservation across the plan",
                spec.name
            );
            let mut rng = Rng::new(0x7E65);
            for i in 0..2 {
                let x = spec.random_input(&mut rng);
                let want = oracle.infer(&x).unwrap();
                let got = tp.infer(&x).unwrap();
                assert_eq!(
                    got.outs[0].features.data, want.features.data,
                    "{} request {i}: auto plan must match the oracle",
                    spec.name
                );
                assert_eq!(got.outs[0].logits, want.logits, "{}", spec.name);
            }
        }

        // and a fully split mobilenet (every layer 2-way, grouped layers
        // cut at group boundaries) matches too
        let spec = ModelSpec::synthetic_mobilenet(1, 16, 6, 0.5, 0x7E66, 4);
        let mut oracle = ChipSession::new(cfg, spec.clone()).unwrap();
        let n_layers = spec.layers.len();
        let plan = HybridPlan::manual(&spec, &cfg, &[(0, n_layers, 2)]).unwrap();
        let mut tp = TensorParallelSession::new(cfg, spec.clone(), plan, hw).unwrap();
        let x = spec.random_input(&mut Rng::new(0x7E67));
        let want = oracle.infer(&x).unwrap();
        let got = tp.infer(&x).unwrap();
        assert_eq!(got.outs[0].features.data, want.features.data, "2-way mobilenet == oracle");
        assert_eq!(got.outs[0].logits, want.logits);
    }
}
