//! Minimal in-tree error type with an `anyhow`-compatible surface.
//!
//! The image is offline (no crates.io), so the crate carries its own
//! error plumbing: a string-backed [`Error`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and a [`Context`] trait for chaining messages.
//! Context chains are joined eagerly with `": "`, so both `{}` and `{:#}`
//! render the full `outer: inner: root` chain the way callers expect.

use std::fmt;

/// A string-backed error with its context chain pre-joined.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string() }
    }

    /// Wrap with an outer context message.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or missing value) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (like `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::error::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::error::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::error::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] (like `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

// Make the macros importable alongside the types: `use crate::error::{anyhow, bail}`.
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        bail!("unconditional")
    }

    #[test]
    fn macros_build_formatted_errors() {
        let e = anyhow!("bad thing {} at {}", 7, "here");
        assert_eq!(e.to_string(), "bad thing 7 at here");
        let inline = 42;
        assert_eq!(anyhow!("value {inline}").to_string(), "value 42");
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(fails(true).unwrap_err().to_string(), "unconditional");
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let e2 = e.context("outermost");
        assert!(format!("{e2:#}").starts_with("outermost: outer: "));
        let missing: Option<u32> = None;
        assert_eq!(missing.with_context(|| "absent").unwrap_err().to_string(), "absent");
    }

    #[test]
    fn from_std_error_works_with_question_mark() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").unwrap_err().to_string().contains("invalid digit"));
    }
}
