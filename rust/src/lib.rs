//! # fat-imc — FAT: an In-Memory Accelerator with Fast Addition for TWNs
//!
//! Full-system reproduction of *FAT* (Zhu et al., IEEE TCAD 2022,
//! DOI 10.1109/TCAD.2022.3184276) as the L3 layer of a rust + JAX + Pallas
//! stack.  The crate contains:
//!
//! - [`circuit`] — device/circuit substrate: MTJ model, FreePDK45-class gate
//!   library, and the four Sense-Amplifier designs (FAT, STT-CiM, ParaPIM,
//!   GraphS) with functional truth tables plus latency / power / area models
//!   calibrated to the paper's Virtuoso measurements.
//! - [`array`] — the Computing Memory Array (CMA): 512x256 STT-MRAM cells in
//!   column-major bit-serial layout, decoders, memory controller, and the
//!   Sparse Addition Control Unit (SACU).
//! - [`addition`] — the four in-memory addition schemes (Fig. 3) as both
//!   bit-accurate executions over a CMA and analytic timing models.
//! - [`ternary`] — TWN quantization (eq. 7), Table III weight encoding,
//!   2-bit packing, sparsity statistics.
//! - [`nn`] — minimal tensor + CNN layer reference implementations, the
//!   ResNet-18 geometry table, the ternary op IR ([`nn::ops`]), and the
//!   non-conv workload builders ([`nn::workloads`]).
//! - [`mapping`] — Img2Col and the five data-mapping schemes of Table VII
//!   (Direct-OS, Img2Col-OS/IS/WS/CS) with the CMA grid planner of Fig. 9.
//! - [`coordinator`] — the 4096-CMA chip: scheduler, DPU (BN + ReLU),
//!   metrics, and the serving stack — single-chip sessions, layer-boundary
//!   sharding, KN tensor parallelism, and a threaded inference server, all
//!   executing on one shared stage fabric ([`coordinator::exec`]).
//! - [`runtime`] — PJRT bridge: loads the AOT-compiled HLO text artifacts
//!   produced by `python/compile/aot.py` and cross-validates the simulator
//!   against XLA execution.  The offline image has no `xla` crate, so the
//!   engine is a graceful stub that reports PJRT as unavailable; the
//!   manifest/signature plumbing is real and tested.
//! - [`error`] — in-tree `anyhow`-style error type and macros (the image is
//!   offline; the crate is dependency-free).
//!
//! ## Ternary op IR
//!
//! Every serving layer is a [`coordinator::model::LayerSpec`]: a
//! [`nn::ops::LayerOp`] — `Conv` (a plain [`nn::resnet::ConvLayer`]),
//! `GroupedConv` ([`nn::ops::GroupedConvLayer`]: `groups` independent
//! convs over contiguous channel slices; depthwise is `cg = kg = 1`),
//! or `Gemm` ([`nn::ops::GemmLayer`], lowered to a degenerate 1x1 conv
//! whose Img2Col is the identity) — plus the per-channel epilogue
//! (folded BN gamma/beta + ReLU, optional 2x2 max pool, and for
//! fused-QKV layers the multi-head attention-score epilogue on the
//! DPU).  Every op answers the same planning questions through one
//! interface: `units()` (its native conv execution units with channel
//! offsets), `kn()` / `kn_granularity()` (legal KN cut points —
//! grouped convs only split at group boundaries), `slice_kn()` (tensor
//! parallelism), `with_batch_factor()` (micro-batch fusion), `macs()`
//! and `weights()` — so the grid mapper, the sharder, the auto-planner,
//! the threaded servers, and the serving engine are all op-kind
//! agnostic and the byte-identity contracts below hold per op kind,
//! not just for conv chains.  Workload builders beyond ResNet live in
//! [`nn::workloads`] (`ternary_transformer_block`,
//! `mobilenet_style_backbone`; `ModelSpec::synthetic_transformer` /
//! `synthetic_mobilenet` attach synthetic ternary weights).  CLI:
//! `fat workload --net transformer|mobilenet [--auto --chips N
//! [--serve]]`, with the same oracle self-checks as `fat resnet`;
//! `benches/workloads.rs` compares the three compute shapes on equal
//! chips.
//!
//! ## The runtime / session layer
//!
//! The chip is *weight-stationary* (§III-D Combined-Stationary mapping):
//! weights live in the SACU weight registers while activations stream.
//! [`coordinator::session`] models exactly that for serving:
//!
//! - [`coordinator::model::ModelSpec`] — a multi-layer ternary conv
//!   pipeline (filters + folded BN per layer), e.g. the ResNet-18 backbone
//!   from [`nn::resnet`].
//! - [`coordinator::session::LoadedModel`] — the spec planned onto the
//!   grid with every SACU weight register packed **once**; the one-time
//!   cost is captured in split `loading` metrics (`weight_load_ns`,
//!   `weight_reg_writes`).  A model whose register footprint exceeds
//!   [`coordinator::accelerator::ChipConfig::wreg_capacity`] is rejected —
//!   one chip cannot keep it stationary.
//! - [`coordinator::session::ChipSession`] — serves batched activations
//!   against the resident weights: per-request metrics report **zero**
//!   weight-register writes, so loading amortizes across requests exactly
//!   as on the physical chip.  Its `infer_many` fuses same-shape requests
//!   along N (micro-batching) with bit-identical re-split.
//!
//! ## Sharding: models bigger than one chip
//!
//! [`coordinator::sharding`] lifts serving to N chips:
//!
//! - [`coordinator::sharding::ShardPlan`] — cuts a validated model at
//!   layer boundaries into contiguous shards balanced by weight-register
//!   footprint (max shard ≤ ceil(total/N) + one layer).
//! - [`coordinator::sharding::PipelineSession`] — one resident session
//!   per shard, chained; every boundary charges an inter-chip transfer on
//!   the quantized activations (`xfer_bytes` / `xfer_ns` in
//!   [`coordinator::metrics::ChipMetrics`], costed from
//!   [`mapping::schemes::HwParams`] link bandwidth + latency).  The
//!   pipeline is byte-identical to the single-chip session — both run the
//!   same `run_quantized` stage code — and per-shard loading sums to the
//!   unsharded register-write total.
//! - [`coordinator::server::InferenceServer`] — the threaded front-end,
//!   in three modes: `Replicated` (a resident replica per worker over a
//!   CMA slice, with a queue-depth-aware micro-batcher), `Pipelined`
//!   (workers are shard *stages* connected by channels, so shard k
//!   computes request i+1 while shard k+1 computes request i), and
//!   `Hybrid` (any auto-planned pipeline of tensor-parallel groups on
//!   the same channel fabric — see the next sections).  The staged head
//!   runs the same micro-batcher: a fused tensor crosses each boundary
//!   as **one** transfer, amortizing the per-leg hop latency over the
//!   batch.
//!
//! ## Tensor parallelism: layers bigger than one chip
//!
//! Layer-boundary sharding cannot help when a *single* layer's weight
//! registers exceed one chip — [`coordinator::sharding::ShardPlan`]
//! rejects that case outright.  [`coordinator::tensor_parallel`] extends
//! the paper's Combined-Stationary KN unrolling (§III-C) *across* chips:
//!
//! - [`coordinator::tensor_parallel::TensorPlan`] — one layer's KN
//!   filters cut into contiguous per-chip slices; the footprint is
//!   linear in the slice width, so near-equal slices are balanced by
//!   construction and each is checked against
//!   [`coordinator::accelerator::ChipConfig::wreg_capacity`].
//! - [`coordinator::tensor_parallel::TensorParallelSession`] — serves a
//!   [`coordinator::tensor_parallel::HybridPlan`], a pipeline of
//!   tensor-parallel groups (`ways = 1` stages are plain shards).  Every
//!   split layer computes per-slice partial feature maps on the
//!   [`coordinator::session::ChipSession::run_layer_raw`] stage
//!   primitive, ring-all-gathers them (scale maxima, then the quantized
//!   partials) over the link model into `ChipMetrics::{xfer_bytes,
//!   xfer_ns, xfer_legs}`, and requantizes the gathered tensor through
//!   the exact code the single chip runs — so KN-split serving is
//!   **byte-identical** to the single-chip oracle and register writes
//!   are conserved across slices (every filter loads once, somewhere).
//! - [`coordinator::tensor_parallel::plan_auto`] — the latency-balanced
//!   auto-planner: per-layer latencies are *simulated* at candidate
//!   split widths (costs are value-independent, so one synthetic request
//!   prices a width exactly), then a DP over contiguous stage cuts and
//!   per-stage widths minimizes the bottleneck stage for a target chip
//!   count.  [`coordinator::sharding::ShardPlan::partition_weighted`] is
//!   the same latency objective restricted to pure layer-boundary cuts.
//!
//! ## One execution fabric under every serving path
//!
//! All of the above execute on [`coordinator::exec`], the shared
//! stage fabric: [`coordinator::exec::StagePlan`] (a plain shard or a
//! tensor-parallel group) builds into a
//! [`coordinator::exec::StageRunner`], and one runner implementation
//! owns boundary-leg charging, per-stage fault-seed derivation, the
//! micro-batch drain, and the fused-capacity gate.  Inside a TP stage
//! each KN slice chip computes its `run_layer_raw` partials on its own
//! scoped thread (fan-out/fan-in, joined in slice order so the f64
//! metric folds stay deterministic), then the gathers are charged
//! exactly as inline.  [`coordinator::sharding::PipelineSession`] and
//! [`coordinator::tensor_parallel::TensorParallelSession`] are thin
//! facades over the same runners, and
//! `ServingMode::Hybrid { plan, max_batch }` serves any
//! [`coordinator::tensor_parallel::plan_auto`] output on the threaded
//! channel pipeline — the refactor contract, pinned by tests and the
//! `hybrid_serving` bench, is **byte-identity** (outputs and full
//! [`coordinator::metrics::ChipMetrics`]) between the threaded server
//! and the inline sessions.
//!
//! CLI: `fat plan --chips N` (profile + plan tables), `fat resnet --auto
//! --chips N [--serve]` (inline self-checks, then optionally the same
//! plan replayed through the hybrid server), `fat serve --mode
//! pipelined --shards N --max-batch B`, `fat serve --mode hybrid
//! --chips N --max-batch B`.  See `examples/tensor_parallel.rs`,
//! `examples/hybrid_serve.rs`, `benches/tensor_parallel.rs`, and
//! `benches/hybrid_serving.rs`.
//!
//! ## Serving engine: continuous batching over the fabric
//!
//! [`coordinator::engine`] puts a continuous-batching scheduler in
//! front of the fabric, closing the loop from open-loop arrivals to
//! SLO-aware service:
//!
//! - **Admission control / backpressure** — a bounded queue sized from
//!   the register-footprint-clamped fused window
//!   (`queue_windows x effective_batch` by default); a full queue
//!   *rejects* at submit rather than buffering unboundedly.  The plain
//!   [`coordinator::server::InferenceServer`] shares the contract via
//!   `start_bounded` / `try_submit` /
//!   [`coordinator::server::SubmitError::QueueFull`].
//! - **In-flight batch re-forming** — each fused window is re-formed
//!   from whatever is queued at dispatch time (late arrivals join the
//!   next window; nothing waits for a fixed batch to fill).  Per-request
//!   requant-scale calibration is preserved through the same
//!   `quantize_entry` + fused-capacity clamp the sessions use, so every
//!   served response is **byte-identical** — outputs *and* simulated
//!   [`coordinator::metrics::ChipMetrics`] — to the inline session
//!   replaying the logged windows (test- and bench-gated).
//! - **SLO-aware scheduling** — `SchedPolicy::SloEdf` orders a
//!   two-level queue (interactive over batch, earliest-deadline-first
//!   within class) and *sheds* requests whose deadline cannot be met by
//!   the feasibility horizon, keeping served-request p99 bounded at
//!   overload; `SchedPolicy::FifoDequeue` is the dequeue-fusion
//!   baseline whose p99 collapses there.  Shed counts are first-class
//!   [`coordinator::engine::EngineStats`], not hidden timeouts.
//! - **Open-loop harness** — [`coordinator::engine::poisson_trace`]
//!   draws a deterministic Poisson arrival trace and
//!   `ServingEngine::run_trace` replays it on the *simulated* clock
//!   (windows advance virtual time by their fused `latency_ns`), so
//!   goodput / p50 / p99 / p999 curves are reproducible across runs
//!   and hosts.  `serve()` runs the same scheduler live on a host
//!   thread.  CLI: `fat loadgen --load 3 --seed 7` (or `--rate R
//!   --duration S`); see `examples/serving_engine.rs` and
//!   `benches/serving_engine.rs` (emits `BENCH_serving_engine.json`,
//!   CI-gated at >= 1.5x baseline goodput at overload).
//!
//! ## Compute fidelity: bit-serial execution vs exact ledger replay
//!
//! Every compute path is governed by
//! [`coordinator::accelerator::ChipConfig::fidelity`]
//! ([`array::sacu::Fidelity`]):
//!
//! - **`BitSerial`** — cycle-accurate emulation: each SACU sparse dot
//!   walks real CMA rows through `sense_two_rows` / `write_row_masked`
//!   per bit per addition.  Storage state, endurance, and injected
//!   sensing faults are physical.
//! - **`Ledger`** (the serving default) — the dot product is computed
//!   with host integer arithmetic over the operand slots, and an **exact
//!   ledger replay** charges `CmaStats` with precisely the senses /
//!   writes / latency / energy the bit-serial path would have recorded,
//!   derived per addition scheme from the same `SparseDotPlan`
//!   ([`addition::AdditionScheme::replay_add_costs`],
//!   [`array::cma::Cma::replay_store_vector`]).
//!
//! The faithfulness argument: when no fault fires, the bit-serial result
//! is exact two's-complement arithmetic *by construction* (pinned by
//! `all_schemes_add_exactly` and `sparse_dot_matches_plain_dot_product`),
//! and every scheme's cost is value-independent — so `DotResult` **and**
//! `CmaStats`/`ChipMetrics` are byte-identical between the two modes.
//! This is not assumed but gated: property suites compare the fidelities
//! across all four schemes x layouts x widths x sparsities x masks
//! (`ledger_fidelity_matches_bit_serial_exactly`), at chip level, and end
//! to end through `ChipSession` / `PipelineSession` — and the FAT paper's
//! own headline numbers are themselves ledger quantities (operation
//! counts x calibrated per-op costs, eqs. 1–3), so nothing the
//! reproduction reports depends on per-bit storage state.  The win is an
//! order of magnitude of host time on fault-free serving
//! (`benches/hotpath.rs`, CI-gated).
//!
//! Demotion: [`coordinator::accelerator::ChipConfig::effective_fidelity`]
//! falls back to `BitSerial` whenever fault injection is armed at a
//! positive BER — flips corrupt the real comparator words the ledger
//! path never materializes.  A reliability sweep therefore computes its
//! oracle and zero-BER points on the fast path and pays for
//! cycle-accurate emulation only where flips can land.  CLI:
//! `--fidelity ledger|bit-serial` on `infer` / `resnet` / `serve`.
//!
//! ## Fault injection and the model-scale reliability sweep
//!
//! The paper's §IV-A3 argues FAT's two-operand sensing has a 2.4x larger
//! sense margin than three-operand designs (ParaPIM/GraphS), hence
//! orders of magnitude fewer sensing flips.  The stack models that end
//! to end:
//!
//! - [`circuit::reliability`] — the physical layer: per-sense bit-error
//!   rates from the MTJ sense margins under Gaussian noise
//!   (`sense_bit_error_rate`, ~5e-8 for FAT vs ~2.6e-2 for the
//!   three-operand designs; `sa_sense_bers` lists all four).
//! - [`coordinator::accelerator::ChipConfig::fault`] — arms sensing-fault
//!   injection on every CMA of a chip ([`coordinator::accelerator::SenseFault`]).
//!   Corruption streams are deterministic per (seed, request, layer,
//!   tile) regardless of thread scheduling; the serving layers re-seed
//!   per worker/pipeline stage so replicas decorrelate.  At `ber = 0.0`
//!   the armed chip is byte-identical to the ideal chip — the hook never
//!   perturbs values or timing unless a flip fires.
//! - [`mapping::schemes::HwParams::link_ber`] — the sharded stack's extra
//!   error source: every pipeline boundary flips bits of the transported
//!   quantized activations at the link's bit-error rate.
//! - [`mapping::schemes::HwParams::link_ecc`] — SECDED(72,64) on the
//!   link: each receiving stage corrects single-bit flips per 64-bit
//!   flit, at +12.5% wire bytes charged on every transfer leg
//!   ([`mapping::schemes::HwParams::wire_bytes`]).  `fat reliability
//!   --link-ecc` sweeps the protected link against the raw one — the
//!   accuracy-vs-overhead trade-off of ECC on a lossy interconnect.
//! - [`coordinator::reliability::sweep_model`] — the model-scale sweep:
//!   one resident model (single chip, N-replica pool, or N-shard
//!   pipeline), loaded once and re-armed per BER point, a fixed input
//!   set served end to end, and top-1 agreement / logit MSE scored
//!   against the fault-free oracle, with each SA design's physical
//!   sense BER mapped onto the resulting curve.  CLI: `fat reliability
//!   --bers 0,1e-6,1e-3,2.6e-2 [--workers 2 | --shards 2
//!   --link-bers 0,1e-6,1e-4,1e-3]`; see `examples/reliability.rs` and
//!   `benches/reliability_sweep.rs`.
//!
//! ## Fault tolerance
//!
//! Reliability answers "how wrong do outputs get"; fault *tolerance*
//! answers "does serving survive".  The chip fault model
//! ([`coordinator::reliability::ChipFault`]: fail-stop, hang, transient
//! corruption — armed per fleet chip, or drawn as a seeded Poisson
//! schedule by [`coordinator::reliability::poisson_chip_failures`])
//! drives [`coordinator::failover::TolerantFabric`], the recovery layer
//! under the serving engine: pre-flight fail-stop detection, per-stage
//! watchdog deadlines profiled from the plan, panic containment for TP
//! slice threads (a typed [`coordinator::exec::StageError`], never a
//! poisoned fabric), chip quarantine + re-planning over the survivors
//! (+ idle spares) with the *real* weight-reload cost charged to the
//! recovering window ([`coordinator::metrics::ChipMetrics::reload_ns`]),
//! bounded retries that shed exhausted windows as typed failures
//! (`EngineReply::Failed` / `TraceReport::failed`) instead of hanging
//! collectors, and an optional ABFT output checksum against a
//! Ledger-fidelity shadow for silent-corruption detection.  Contracts:
//! conservation is exact (`served + shed + failed == admitted`, one
//! reply per request), surviving outputs stay byte-identical to the
//! solo oracle across a re-plan, and the fault-free path is
//! bit-identical — outputs AND metrics — to the plain engine with every
//! recovery counter at zero.  CLI: `fat serve --mode hybrid
//! --inject-fail-stop chip:req --spares n` and `fat loadgen --chip-mtbf
//! windows --spares n`; see `benches/fault_tolerance.rs`.
//!
//! ## Observability: deterministic tracing and metrics
//!
//! [`coordinator::telemetry`] instruments the whole serving stack on the
//! **simulated** clock, so telemetry is as reproducible as the serving
//! results themselves:
//!
//! - **Span tracing** — every request's lifecycle (`admit` → `queue` →
//!   window dispatch → per-stage `compute` / `reduce` / `dpu` /
//!   `all_gather` legs → `reply` / `shed` / `failed`) plus every
//!   failover event (`chip_failed` / `watchdog_fire` instants,
//!   `quarantine`, `weight_reload`, `replan`, `sdc_retry`) is recorded
//!   through the [`coordinator::telemetry::TraceSink`] trait.  The
//!   default [`coordinator::telemetry::NullSink`] reports
//!   `enabled() == false`, so the hot path never formats an event —
//!   spans are a *read-only derivation* of the already-charged
//!   [`coordinator::metrics::ChipMetrics`], and an armed run returns a
//!   report byte-identical to an untraced one (bench-gated).
//! - **Chrome/Perfetto export** —
//!   [`coordinator::telemetry::chrome_trace_json`] renders a
//!   [`coordinator::telemetry::TraceBuffer`] as trace-event JSON
//!   (pid = fleet chip, tid = stage / request, `ts`/`dur` = simulated
//!   ns); [`coordinator::telemetry::validate_chrome_trace`] re-parses
//!   it with [`minijson`] and checks per-track timestamp monotonicity
//!   and span nesting.  Identical runs export byte-identical files.
//! - **Metrics registry** — [`coordinator::telemetry::MetricsRegistry`]
//!   holds `fat_*` counters, gauges, and fixed log-bucket histograms
//!   with deterministic Prometheus text exposition, and
//!   `TraceReport::stall_attribution` derives where served requests'
//!   time went (queueing vs compute vs reduce vs dpu vs transfer vs
//!   reload).
//!
//! CLI: `fat loadgen --trace-out run.json --metrics-out run.prom` and
//! `fat serve --mode hybrid [--inject-fail-stop chip:req] --trace-out
//! f.json` (both self-validate the trace before writing); see
//! `examples/trace_export.rs` and `benches/telemetry.rs`.

pub mod addition;
pub mod array;
pub mod bench_harness;
pub mod circuit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod mapping;
pub mod minijson;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod ternary;
pub mod testutil;

pub use config::FatConfig;
