//! `fat` — leader entrypoint for the FAT accelerator reproduction.

use fat_imc::cli::{Args, HELP};
use fat_imc::config::FatConfig;
use fat_imc::coordinator::accelerator::{ChipConfig, FatChip};
use fat_imc::coordinator::engine::{
    poisson_trace, EngineConfig, EngineReply, SchedPolicy, ServingEngine, SloClass, TraceConfig,
};
use fat_imc::coordinator::failover::{ArmedFault, FailoverConfig};
use fat_imc::coordinator::model::ModelSpec;
use fat_imc::coordinator::reliability::{poisson_chip_failures, ChipFault};
use fat_imc::coordinator::server::{latency_percentiles, InferenceServer, Request, ServingMode};
use fat_imc::coordinator::session::{op_wreg_footprint, ChipSession};
use fat_imc::coordinator::sharding::{PipelineSession, ShardPlan};
use fat_imc::coordinator::telemetry::{
    chrome_trace_json, validate_chrome_trace, MetricsRegistry, TraceBuffer,
};
use fat_imc::coordinator::tensor_parallel::{
    plan_auto, profile_layers, HybridPlan, TensorParallelSession,
};
use fat_imc::error::Result;
use fat_imc::mapping::schemes::{evaluate_all, HwParams};
use fat_imc::nn::layers::TernaryFilter;
use fat_imc::nn::ops::LayerOp;
use fat_imc::nn::resnet::{resnet18_conv_layers, ConvLayer};
use fat_imc::nn::tensor::Tensor4;
use fat_imc::report::{ratio, Table};
use fat_imc::runtime::engine::Engine;
use fat_imc::runtime::verify::verify_ternary_gemm;
use fat_imc::testutil::Rng;
use fat_imc::addition::scheme;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&raw) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

/// Export what a traced run collected: self-validate the Chrome trace
/// before writing it (an invalid trace is a bug in the instrumentation,
/// not a file for the user), then write the Prometheus exposition.
fn export_telemetry(
    buf: Option<&std::sync::Arc<TraceBuffer>>,
    registry: Option<&std::sync::Arc<MetricsRegistry>>,
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
) -> Result<()> {
    if let (Some(buf), Some(path)) = (buf, trace_out) {
        let json = chrome_trace_json(&buf.snapshot());
        let s = validate_chrome_trace(&json)
            .map_err(|e| fat_imc::anyhow!("exported trace failed self-validation: {e:#}"))?;
        std::fs::write(path, &json).map_err(|e| fat_imc::anyhow!("writing {path}: {e}"))?;
        println!(
            "  trace: {} events ({} spans, {} instants) on {} tracks -> {path} \
(open in ui.perfetto.dev)",
            s.events, s.spans, s.instants, s.tracks
        );
    }
    if let (Some(reg), Some(path)) = (registry, metrics_out) {
        let text = reg.expose();
        std::fs::write(path, &text).map_err(|e| fat_imc::anyhow!("writing {path}: {e}"))?;
        println!("  metrics: {} lines of Prometheus text -> {path}", text.lines().count());
    }
    Ok(())
}

/// `--fidelity ledger|bit-serial`; `None` keeps the config's default.
fn fidelity_flag(args: &Args) -> Result<Option<fat_imc::coordinator::accelerator::Fidelity>> {
    args.get("fidelity").map(fat_imc::config::parse_fidelity).transpose()
}

fn pick_layer(idx: usize) -> Result<ConvLayer> {
    let layers = resnet18_conv_layers();
    if idx == 0 || idx > layers.len() {
        fat_imc::bail!("--layer must be 1..={}", layers.len());
    }
    Ok(layers[idx - 1])
}

/// Shrink an ImageNet-geometry layer to a simulable scale while keeping
/// channel structure (the full geometry is for the analytic model).
fn shrink(mut l: ConvLayer) -> ConvLayer {
    l.n = 1;
    l.h = l.h.min(14);
    l.w = l.w.min(14);
    l.c = l.c.min(32);
    l.kn = l.kn.min(16);
    l
}

fn run(raw: &[String]) -> Result<()> {
    let args = match Args::parse(raw) {
        Ok(a) => a,
        Err(_) => {
            println!("{HELP}");
            return Ok(());
        }
    };
    match args.command.as_str() {
        "help" | "-h" | "--help" => {
            println!("{HELP}");
            Ok(())
        }
        "info" => cmd_info(&args),
        "infer" => cmd_infer(&args),
        "map" => cmd_map(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "resnet" => cmd_resnet(&args),
        "workload" => cmd_workload(&args),
        "plan" => cmd_plan(&args),
        "sweep" => cmd_sweep(&args),
        "reliability" => cmd_reliability(&args),
        other => {
            println!("unknown command `{other}`\n\n{HELP}");
            std::process::exit(2);
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    args.allow(&["config", "artifacts"])?;
    let cfg = match args.get("config") {
        Some(p) => FatConfig::from_file(std::path::Path::new(p))?,
        None => FatConfig::default(),
    };
    println!("FAT chip configuration:");
    println!("  CMAs: {} x 512x256 STT-MRAM ({} MiB)", cfg.cmas, cfg.cmas * 512 * 256 / 8 / 1024 / 1024);
    println!("  SA design: {:?} | skip zeros: {} | layout: {}", cfg.sa, cfg.skip_zeros,
        if cfg.interval_layout { "interval (CS)" } else { "dense (IS)" });
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    match Engine::load(&dir) {
        Ok(engine) => {
            println!("  PJRT platform: {}", engine.platform());
            let mut names = engine.names();
            names.sort();
            for n in names {
                let info = engine.info(n).unwrap();
                println!("  artifact `{n}`: {} inputs -> {:?}", info.inputs.len(), info.outputs[0].shape);
            }
        }
        Err(e) => println!("  artifacts: unavailable ({e:#})"),
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    args.allow(&["sparsity", "layer", "baseline", "config", "fidelity"])?;
    let sparsity = args.get_f64("sparsity", 0.8)?;
    let layer = shrink(pick_layer(args.get_usize("layer", 10)?)?);
    let mut chip_cfg = if args.get_bool("baseline") {
        ChipConfig::parapim_baseline()
    } else {
        match args.get("config") {
            Some(p) => FatConfig::from_file(std::path::Path::new(p))?.chip(),
            None => ChipConfig::fat(),
        }
    };
    if let Some(f) = fidelity_flag(args)? {
        chip_cfg.fidelity = f;
    }

    let mut rng = Rng::new(42);
    let mut x = Tensor4::zeros(layer.n, layer.c, layer.h, layer.w);
    x.fill_random_ints(&mut rng, 0, 256);
    let filter = TernaryFilter::new(
        layer.kn, layer.c, layer.kh, layer.kw,
        rng.ternary_vec(layer.kn * layer.j_dim(), sparsity),
    );

    println!(
        "running {} (shrunk to N={} C={} {}x{} KN={}) at sparsity {:.0}% on {:?} \
({:?} fidelity)...",
        layer.name, layer.n, layer.c, layer.h, layer.w, layer.kn, sparsity * 100.0,
        chip_cfg.sa_kind, chip_cfg.effective_fidelity()
    );
    let chip = FatChip::new(chip_cfg);
    let run = chip.run_conv_layer(&x, &filter, &layer);
    let m = &run.metrics;
    println!("  simulated latency : {:.1} us", m.latency_ns / 1e3);
    println!("  simulated energy  : {:.1} nJ", m.energy_pj / 1e3);
    println!("  vector additions  : {}", m.adds);
    println!("  null ops skipped  : {}", m.skipped);
    println!("  array senses/writes: {}/{}", m.senses, m.writes);
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    args.allow(&["layer"])?;
    let layer = pick_layer(args.get_usize("layer", 10)?)?;
    let fat = scheme(fat_imc::circuit::sense_amp::SaKind::Fat);
    let costs = evaluate_all(&layer, &HwParams::default(), fat.as_ref());
    let direct = costs[0].total_ns();
    let mut t = Table::new(
        &format!("Mapping comparison on {} (Table VII/VIII)", layer.name),
        &["mapping", "x-load(ns)", "w-load(ns)", "compute(ns)", "total(ns)", "speedup", "par.cols", "util", "maxwrite"],
    );
    for c in &costs {
        t.row(vec![
            c.kind.name().into(),
            format!("{:.0}", c.x_load_ns),
            format!("{:.0}", c.w_load_ns),
            format!("{:.0}", c.compute_ns),
            format!("{:.0}", c.total_ns()),
            ratio(direct / c.total_ns()),
            format!("{}/256", c.parallel_cols),
            format!("{:.1}%", c.utilization * 100.0),
            format!("{}x", c.max_cell_write_factor),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    args.allow(&["artifacts", "sparsity"])?;
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let sparsity = args.get_f64("sparsity", 0.5)?;
    println!("loading artifacts from {dir:?}...");
    let engine = Engine::load(&dir)?;
    println!("platform: {}", engine.platform());
    let rep = verify_ternary_gemm(&engine, 7, sparsity)?;
    println!(
        "verify `{}`: {} elements, max |err| = {} -> {}",
        rep.name,
        rep.elements,
        rep.max_abs_err,
        if rep.exact { "EXACT MATCH (bit-serial simulator == XLA Pallas kernel)" } else { "close" }
    );
    Ok(())
}

/// Fig. 14 from the command line: network-level sparsity sweep.
fn cmd_sweep(args: &Args) -> Result<()> {
    use fat_imc::coordinator::scheduler::{analytic_compute_metrics, AnalyticConfig};
    use fat_imc::mapping::schemes::MappingKind;
    args.allow(&["from", "to", "step"])?;
    let from = args.get_f64("from", 0.0)?;
    let to = args.get_f64("to", 0.9)?;
    let step = args.get_f64("step", 0.1)?;
    fat_imc::ensure!(step > 0.0 && from <= to, "need from <= to and step > 0");
    let layers = resnet18_conv_layers();
    let mut fat_cfg = AnalyticConfig::fat();
    let mut para_cfg = AnalyticConfig::parapim_baseline();
    fat_cfg.mapping = MappingKind::Img2ColIs;
    para_cfg.mapping = MappingKind::Img2ColIs;
    let mut t = Table::new(
        "ResNet-18 vs ParaPIM across sparsity (Fig. 14 sweep)",
        &["sparsity", "FAT (us)", "ParaPIM (us)", "speedup", "energy eff"],
    );
    let mut s = from;
    while s <= to + 1e-9 {
        let (mut f_ns, mut p_ns, mut f_pj, mut p_pj) = (0.0, 0.0, 0.0, 0.0);
        for l in &layers {
            let f = analytic_compute_metrics(l, s, &fat_cfg);
            let p = analytic_compute_metrics(l, s, &para_cfg);
            f_ns += f.latency_ns;
            p_ns += p.latency_ns;
            f_pj += f.energy_pj;
            p_pj += p.energy_pj;
        }
        t.row(vec![
            format!("{:.0}%", s * 100.0),
            format!("{:.1}", f_ns / 1e3),
            format!("{:.1}", p_ns / 1e3),
            ratio(p_ns / f_ns),
            ratio(p_pj / f_pj),
        ]);
        s += step;
    }
    println!("{}", t.render());
    Ok(())
}

/// `fat reliability`: the paper's §IV-A3 sensing-reliability analysis at
/// model scale — sweep a resident ResNet-18 through the serving stack at
/// swept sense (and, sharded, link) bit-error rates and report accuracy
/// against the fault-free oracle, with every SA design's physical sense
/// BER mapped onto the curve.
fn cmd_reliability(args: &Args) -> Result<()> {
    use fat_imc::coordinator::reliability::{ber_str, default_ber_grid, sweep_model, SweepConfig};
    args.allow(&[
        "bers", "link-bers", "link-ecc", "shards", "workers", "requests", "seed", "batch",
        "input", "scale", "sparsity", "classes",
    ])?;
    let link_ecc = args.get_bool("link-ecc");
    let shards = args.get_usize("shards", 1)?;
    let workers = args.get_usize("workers", 1)?;
    let requests = args.get_usize("requests", 4)?.max(1);
    let seed = args.get_usize("seed", 0x5EED)? as u64;
    let batch = args.get_usize("batch", 1)?;
    let input = args.get_usize("input", 16)?;
    let scale = args.get_usize("scale", 16)?;
    let sparsity = args.get_f64("sparsity", 0.7)?;
    let classes = args.get_usize("classes", 10)?;
    let bers = match args.get_f64_list("bers")? {
        Some(b) => b,
        None => default_ber_grid(),
    };
    let link_bers = args.get_f64_list("link-bers")?.unwrap_or_default();

    let spec = ModelSpec::synthetic_resnet18(batch, input, scale, sparsity, seed, classes);
    println!(
        "reliability sweep: {} ({} conv layers, sparsity {:.0}%) on {} at {} BER points, \
{requests} requests per point vs the fault-free oracle",
        spec.name,
        spec.layers.len(),
        spec.sparsity() * 100.0,
        if shards > 1 {
            format!("a {shards}-shard pipeline")
        } else if workers > 1 {
            format!("a {workers}-replica pool")
        } else {
            "a single chip".to_string()
        },
        bers.len(),
    );
    println!(
        "  sense BER grid: [{}]",
        bers.iter().map(|&b| ber_str(b)).collect::<Vec<_>>().join(", ")
    );
    let sc = SweepConfig { bers, link_bers, link_ecc, shards, workers, requests, seed };
    let t0 = std::time::Instant::now();
    let rep = sweep_model(ChipConfig::fat(), &spec, &sc)?;
    if link_ecc {
        println!(
            "SECDED link ECC armed: single-bit flips per 64-bit flit corrected at every \
stage, +12.5% wire bytes per leg (compare a run without --link-ecc for the trade-off)"
        );
    }
    println!("{}", rep.table().render());
    println!("{}", rep.anchor_table().render());
    // the headline: what FAT's sense margin buys at model scale.  Quote
    // each design's *physical* sense BER and say which swept point scored
    // it — on a coarse custom grid the nearest point can be far away, and
    // conflating the two would misattribute the grid point's BER to FAT.
    use fat_imc::circuit::sense_amp::SaKind;
    let anchor = |kind: SaKind| {
        rep.anchors
            .iter()
            .find(|a| a.kind == kind)
            .map(|a| (a.sense_ber, &rep.points[a.nearest_point]))
            .expect("anchors cover every design")
    };
    let (fat_ber, fat_pt) = anchor(SaKind::Fat);
    let (para_ber, para_pt) = anchor(SaKind::ParaPim);
    println!(
        "FAT's 2.4x sense margin at model scale: {:.1}% top-1 agreement near its physical \
~{} sense BER (scored at swept point {}) vs {:.1}% for a ParaPIM-class three-operand SA \
(physical ~{}, scored at {}) — {:.2} s host time",
        fat_pt.top1_agreement * 100.0,
        ber_str(fat_ber),
        ber_str(fat_pt.sense_ber),
        para_pt.top1_agreement * 100.0,
        ber_str(para_ber),
        ber_str(para_pt.sense_ber),
        t0.elapsed().as_secs_f64()
    );
    if fat_pt.link_ber > 0.0 || para_pt.link_ber > 0.0 {
        println!(
            "  note: the scored points carry link BER {}/{} on top of the sense BER — a \
co-swept lossy link combines both error sources; sweep with --link-bers 0 to isolate \
the sense margin",
            ber_str(fat_pt.link_ber),
            ber_str(para_pt.link_ber)
        );
    }
    if let Some(p0) = rep.points.iter().find(|p| p.sense_ber == 0.0 && p.link_ber == 0.0) {
        fat_imc::ensure!(
            p0.bit_identical,
            "zero-BER point diverged from the fault-free oracle — injection plumbing is \
perturbing the hot path"
        );
        println!("zero-BER self-check: bit-identical to the fault-free oracle");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.allow(&[
        "requests", "workers", "batch", "input", "scale", "sparsity", "classes", "mode",
        "shards", "chips", "max-batch", "fidelity", "inject-fail-stop", "spares",
        "trace-out", "metrics-out",
    ])?;
    let n_req = args.get_usize("requests", 16)?.max(1);
    let workers = args.get_usize("workers", 4)?;
    let batch = args.get_usize("batch", 1)?;
    let input = args.get_usize("input", 16)?;
    let scale = args.get_usize("scale", 16)?;
    let sparsity = args.get_f64("sparsity", 0.7)?;
    let classes = args.get_usize("classes", 10)?;
    let shards = args.get_usize("shards", 2)?;
    let chips = args.get_usize("chips", 2)?;
    let max_batch = args.get_usize("max-batch", 1)?;
    let spec = ModelSpec::synthetic_resnet18(batch, input, scale, sparsity, 7, classes);
    let mut chip_cfg = ChipConfig::fat();
    if let Some(f) = fidelity_flag(args)? {
        chip_cfg.fidelity = f;
    }
    // telemetry rides the engine fabric, which only exists for hybrid
    // plans (the replicated/pipelined servers have no trace hooks)
    let trace_out = args.get("trace-out");
    let metrics_out = args.get("metrics-out");
    if (trace_out.is_some() || metrics_out.is_some())
        && args.get_or("mode", "replicated") != "hybrid"
    {
        fat_imc::bail!("--trace-out/--metrics-out need --mode hybrid (telemetry rides the engine fabric)");
    }
    // fault injection rides the fault-tolerant engine path, which only
    // exists for hybrid plans (failover re-plans over the fleet)
    if let Some(s) = args.get("inject-fail-stop") {
        if args.get_or("mode", "replicated") != "hybrid" {
            fat_imc::bail!("--inject-fail-stop needs --mode hybrid (failover re-plans the fleet)");
        }
        let (chip, fault) = ChipFault::parse_fail_stop(s)?;
        let spares = args.get_usize("spares", 0)?;
        return serve_on_engine(
            chip_cfg,
            spec,
            chips,
            max_batch,
            n_req,
            spares,
            vec![ArmedFault { chip, fault }],
            trace_out,
            metrics_out,
        );
    }
    if args.get("spares").is_some() {
        fat_imc::bail!("--spares only matters with --inject-fail-stop (idle spares for failover)");
    }
    // mode-mismatched flags are an error, not silently dropped: a user who
    // asks for --shards must not end up benchmarking an unsharded pool
    let mode = match args.get_or("mode", "replicated") {
        "replicated" => {
            if args.get("shards").is_some() {
                fat_imc::bail!("--shards needs --mode pipelined");
            }
            if args.get("chips").is_some() {
                fat_imc::bail!("--chips needs --mode hybrid");
            }
            ServingMode::Replicated { workers, max_batch }
        }
        "pipelined" => {
            if args.get("workers").is_some() {
                fat_imc::bail!("--workers applies to replicated mode; pipelined stages come from --shards");
            }
            if args.get("chips").is_some() {
                fat_imc::bail!("--chips needs --mode hybrid");
            }
            ServingMode::Pipelined { shards, max_batch }
        }
        "hybrid" => {
            if args.get("workers").is_some() || args.get("shards").is_some() {
                fat_imc::bail!(
                    "hybrid mode plans its own stages from --chips; drop --workers/--shards"
                );
            }
            // a traced serve rides the engine fabric instead of the
            // threaded InferenceServer (same auto plan, same outputs);
            // its windows land on the simulated clock, so the trace is
            // deterministic even though arrivals here are wall-clock
            if trace_out.is_some() || metrics_out.is_some() {
                return serve_on_engine(
                    chip_cfg, spec, chips, max_batch, n_req, 0, Vec::new(), trace_out,
                    metrics_out,
                );
            }
            let plan = plan_auto(&chip_cfg, &spec, chips, &HwParams::default())?;
            print_hybrid_plan(&spec, &plan, chips);
            ServingMode::Hybrid { plan, max_batch }
        }
        other => fat_imc::bail!("--mode must be replicated, pipelined, or hybrid, got `{other}`"),
    };
    let mut rng = Rng::new(7);

    match &mode {
        ServingMode::Replicated { workers, max_batch } => println!(
            "loading {} ({} conv layers, {} ternary weights, sparsity {:.0}%) on {workers} \
workers (micro-batch window {max_batch})...",
            spec.name, spec.layers.len(), spec.weight_count(), spec.sparsity() * 100.0
        ),
        ServingMode::Pipelined { shards, max_batch } => println!(
            "loading {} ({} conv layers, {} ternary weights, sparsity {:.0}%) as a \
{shards}-stage pipeline (micro-batch window {max_batch})...",
            spec.name, spec.layers.len(), spec.weight_count(), spec.sparsity() * 100.0
        ),
        ServingMode::Hybrid { plan, max_batch } => println!(
            "loading {} ({} conv layers, {} ternary weights, sparsity {:.0}%) as a \
{}-stage hybrid pipeline over {} chips (micro-batch window {max_batch})...",
            spec.name,
            spec.layers.len(),
            spec.weight_count(),
            spec.sparsity() * 100.0,
            plan.stages.len(),
            plan.chips()
        ),
    }
    println!("compute path: {:?} fidelity", chip_cfg.effective_fidelity());
    let server = InferenceServer::start_with(chip_cfg, mode.clone(), spec.clone())?;
    // the server clamps the fusion window to what the register files can
    // hold fused; report the effective value when it differs
    match server.mode() {
        ServingMode::Replicated { max_batch: eff, .. }
        | ServingMode::Pipelined { max_batch: eff, .. }
        | ServingMode::Hybrid { max_batch: eff, .. }
            if eff != max_batch =>
        {
            println!("  micro-batch window clamped to {eff} (register capacity)");
        }
        _ => {}
    }
    let load_ns: f64 = server.loading_metrics().iter().map(|m| m.weight_load_ns).sum();
    let load_writes: u64 = server.loading_metrics().iter().map(|m| m.weight_reg_writes).sum();
    println!(
        "  model resident: {load_writes} weight-register writes, {:.1} us one-time load (all workers)",
        load_ns / 1e3
    );

    println!("pushing {n_req} requests...");
    let t0 = std::time::Instant::now();
    for id in 0..n_req as u64 {
        server.submit(Request { id, x: spec.random_input(&mut rng) })?;
    }
    // bounded collect: a bug can fail the run, but never hang it
    let responses = server.collect_timeout(n_req, std::time::Duration::from_secs(600))?;
    let wall = t0.elapsed().as_secs_f64();
    let (p50, p99) = latency_percentiles(responses.iter().map(|r| r.wall_us).collect());
    println!("  served {n_req} requests in {wall:.3}s ({:.1} req/s)", n_req as f64 / wall);
    println!("  host service time p50/p99: {:.0}/{:.0} us", p50, p99);
    // a fused micro-batch shares one run's metrics across its responses:
    // divide by `batched` so the totals count each run once
    let sim_ns: f64 =
        responses.iter().map(|r| r.metrics.latency_ns / r.batched as f64).sum();
    let wreg: u64 = responses.iter().map(|r| r.metrics.weight_reg_writes).sum();
    println!("  simulated compute time total: {:.1} us", sim_ns / 1e3);
    if matches!(mode, ServingMode::Pipelined { .. } | ServingMode::Hybrid { .. }) {
        // fused responses share one run's metrics: divide by `batched` so
        // the totals count each run's transfer exactly once
        let xfer_ns: f64 =
            responses.iter().map(|r| r.metrics.xfer_ns / r.batched as f64).sum();
        let xfer_bytes: f64 =
            responses.iter().map(|r| r.metrics.xfer_bytes as f64 / r.batched as f64).sum();
        println!(
            "  inter-chip transfer total: {:.0} bytes, {:.1} us over the link",
            xfer_bytes,
            xfer_ns / 1e3
        );
    }
    println!(
        "  per-request weight-register writes: {wreg} (weights are resident); \
naive path would have paid the {:.1} us load {n_req} more times",
        load_ns / 1e3
    );
    server.shutdown();
    Ok(())
}

/// `fat serve --mode hybrid` on the live engine fabric — the path behind
/// `--inject-fail-stop chip:req [--spares n]` (kill the named fleet chip
/// at the named window and prove the serving contract under failure:
/// exactly one reply per request, served outputs byte-identical to a solo
/// oracle, recovery paying the real weight-reload cost) and behind
/// `--trace-out`/`--metrics-out` (same engine, no faults armed, telemetry
/// exported on the simulated clock).
#[allow(clippy::too_many_arguments)]
fn serve_on_engine(
    cfg: ChipConfig,
    spec: ModelSpec,
    chips: usize,
    max_batch: usize,
    n_req: usize,
    spares: usize,
    faults: Vec<ArmedFault>,
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
) -> Result<()> {
    let hw = HwParams::default();
    let plan = plan_auto(&cfg, &spec, chips, &hw)?;
    print_hybrid_plan(&spec, &plan, chips);
    for f in &faults {
        println!(
            "arming {:?} on fleet chip {} ({} plan chips + {spares} spares)",
            f.fault,
            f.chip,
            plan.chips()
        );
    }
    let mut engine = ServingEngine::with_fault_tolerance(
        cfg,
        spec.clone(),
        plan,
        hw,
        SchedPolicy::SloEdf,
        EngineConfig { max_batch, queue_windows: 4, queue_depth: Some(n_req.max(1)) },
        FailoverConfig { spares, ..Default::default() },
        faults,
    )?;
    let trace_buf = trace_out.map(|_| std::sync::Arc::new(TraceBuffer::new()));
    let registry = metrics_out.map(|_| std::sync::Arc::new(MetricsRegistry::new()));
    if let Some(buf) = &trace_buf {
        engine.set_trace_sink(buf.clone());
    }
    if let Some(reg) = &registry {
        engine.set_metrics_registry(reg.clone());
    }
    let server = engine.serve();

    let mut rng = Rng::new(7);
    let xs: Vec<Tensor4> = (0..n_req).map(|_| spec.random_input(&mut rng)).collect();
    println!("pushing {n_req} requests through the live fault-tolerant engine...");
    for (id, x) in xs.iter().enumerate() {
        server
            .submit(id as u64, x.clone(), SloClass::Batch, 1e12)
            .map_err(|e| fat_imc::anyhow!("submit {id}: {e}"))?;
    }
    let replies = server.collect_timeout(n_req, std::time::Duration::from_secs(600))?;
    let stats = server.stats();
    server.shutdown();

    let mut served = Vec::new();
    let mut shed = 0usize;
    let mut failed = Vec::new();
    for r in replies {
        match r {
            EngineReply::Served(resp) => served.push(resp),
            EngineReply::Shed { .. } => shed += 1,
            EngineReply::Failed { id, reason, .. } => failed.push((id, reason)),
        }
    }
    // each recovering window carries the failover charge once, shared by
    // its fused requests
    let reload_ns: f64 =
        served.iter().map(|r| r.metrics.reload_ns / r.batched as f64).sum();
    let failovers: f64 =
        served.iter().map(|r| r.metrics.failovers as f64 / r.batched as f64).sum();
    println!(
        "  replies: {} served, {shed} shed, {} failed (exactly one per request)",
        served.len(),
        failed.len()
    );
    if let Some((id, reason)) = failed.first() {
        println!("  first failure (request {id}): {reason}");
    }
    println!(
        "  failovers absorbed: {failovers:.0}, weight-reload paid: {:.1} us",
        reload_ns / 1e3
    );
    fat_imc::ensure!(
        stats.served + stats.shed + stats.failed == stats.admitted
            && stats.admitted == n_req as u64,
        "accounting must conserve requests under fail-stop, got {stats:?}"
    );

    // served outputs must be byte-identical to the solo oracle even when
    // their window was replayed across a failover re-plan
    let mut oracle = ChipSession::new(cfg, spec)?;
    for r in &served {
        let want = oracle.infer(&xs[r.id as usize])?;
        fat_imc::ensure!(
            r.features.data == want.features.data && r.logits == want.logits,
            "request {} diverged from the solo oracle after failover",
            r.id
        );
    }
    println!("  served outputs byte-identical to the solo oracle");
    export_telemetry(trace_buf.as_ref(), registry.as_ref(), trace_out, metrics_out)?;
    println!("serve OK (fault-tolerant)");
    Ok(())
}

/// Open-loop Poisson load vs the continuous-batching engine: replay one
/// deterministic arrival trace through the SLO-aware engine AND the
/// dequeue-fusion baseline scheduler on a virtual clock, print both
/// sides' accounting and percentiles, and gate engine goodput >= the
/// baseline's — the CI smoke's sanity check lives in this command.
fn cmd_loadgen(args: &Args) -> Result<()> {
    args.allow(&[
        "rate", "load", "duration", "seed", "window", "queue-windows", "deadline-us",
        "interactive", "chips", "fidelity", "batch", "input", "scale", "sparsity", "classes",
        "chip-mtbf", "spares", "trace-out", "metrics-out",
    ])?;
    let batch = args.get_usize("batch", 1)?;
    let input = args.get_usize("input", 16)?;
    let scale = args.get_usize("scale", 16)?;
    let sparsity = args.get_f64("sparsity", 0.7)?;
    let classes = args.get_usize("classes", 10)?;
    let seed = args.get_usize("seed", 0x10AD)? as u64;
    let window = args.get_usize("window", 4)?;
    let queue_windows = args.get_usize("queue-windows", 4)?;
    let chips = args.get_usize("chips", 1)?;
    let spec = ModelSpec::synthetic_resnet18(batch, input, scale, sparsity, 7, classes);
    let mut cfg = ChipConfig::fat();
    if let Some(f) = fidelity_flag(args)? {
        cfg.fidelity = f;
    }
    let hw = HwParams::default();

    // Probe the solo simulated latency once: the default rate, duration,
    // and deadlines all scale from it, so `fat loadgen` is meaningfully
    // overloaded (or not) at any model size.
    let mut probe = ChipSession::new(cfg, spec.clone())?;
    let solo = probe.infer(&spec.random_input(&mut Rng::new(1)))?;
    let solo_us = solo.metrics.latency_ns / 1e3;
    drop(probe);
    let service_rate = 1e6 / solo_us; // solo requests per simulated second
    let rate = match args.get("rate") {
        Some(_) => args.get_f64("rate", 0.0)?,
        None => args.get_f64("load", 3.0)? * service_rate,
    };
    let duration_s = args.get_f64("duration", 160.0 / rate)?;
    let deadline_us = args.get_f64("deadline-us", 10.0 * solo_us)?;
    let share = args.get_f64("interactive", 0.25)?;
    let tc = TraceConfig {
        rate_rps: rate,
        duration_s,
        seed,
        deadline_us,
        interactive_share: share,
        interactive_deadline_us: 0.5 * deadline_us,
    };
    let trace = poisson_trace(&spec, &tc)?;

    // optional chip-failure process: a seeded Poisson fail-stop schedule
    // over the fleet (plan chips + spares), replayed identically through
    // both schedulers so the comparison stays apples-to-apples
    let mtbf = args.get("chip-mtbf").map(|_| args.get_f64("chip-mtbf", 0.0)).transpose()?;
    let spares = args.get_usize("spares", 0)?;
    if mtbf.is_none() && args.get("spares").is_some() {
        fat_imc::bail!("--spares only matters with --chip-mtbf (idle spares for failover)");
    }
    if let Some(m) = mtbf {
        fat_imc::ensure!(m > 0.0, "--chip-mtbf must be a positive window count, got {m}");
    }
    println!(
        "model {}: solo simulated latency {:.1} us ({:.0} req/s solo service rate)",
        spec.name, solo_us, service_rate
    );
    println!(
        "offered: {} requests at {:.0} req/s over {:.4} s simulated ({:.2}x solo load), \
seed {seed:#x}",
        trace.len(),
        rate,
        duration_s,
        rate / service_rate
    );
    println!(
        "SLO: batch deadline {:.1} us, interactive {:.1} us ({:.0}% interactive)",
        deadline_us,
        0.5 * deadline_us,
        share * 100.0
    );

    let build = |policy: SchedPolicy| -> Result<ServingEngine> {
        let config = EngineConfig { max_batch: window, queue_windows, queue_depth: None };
        let plan = if chips > 1 {
            plan_auto(&cfg, &spec, chips, &hw)?
        } else {
            HybridPlan::manual(&spec, &cfg, &[(0, spec.layers.len(), 1)])?
        };
        match mtbf {
            Some(m) => {
                let fleet = chips.max(1) + spares;
                let horizon = trace.len() as u64;
                let schedule = poisson_chip_failures(
                    fleet,
                    m,
                    horizon,
                    fat_imc::testutil::seed_mix(seed, 0xFA17),
                );
                let faults: Vec<ArmedFault> =
                    schedule.iter().map(|&(chip, fault)| ArmedFault { chip, fault }).collect();
                println!(
                    "  chip-failure process: mtbf {m} windows over a {fleet}-chip fleet \
({} failures drawn for a {horizon}-window horizon)",
                    faults.len()
                );
                ServingEngine::with_fault_tolerance(
                    cfg,
                    spec.clone(),
                    plan,
                    hw,
                    policy,
                    config,
                    FailoverConfig { spares, ..Default::default() },
                    faults,
                )
            }
            None => ServingEngine::new(cfg, spec.clone(), plan, hw, policy, config),
        }
    };
    let mut engine = build(SchedPolicy::SloEdf)?;
    // telemetry on the slo-edf side only: the trace replays on the
    // simulated clock, so identical seeds give byte-identical files
    let trace_out = args.get("trace-out");
    let metrics_out = args.get("metrics-out");
    let trace_buf = trace_out.map(|_| std::sync::Arc::new(TraceBuffer::new()));
    let registry = metrics_out.map(|_| std::sync::Arc::new(MetricsRegistry::new()));
    if let Some(buf) = &trace_buf {
        engine.set_trace_sink(buf.clone());
    }
    if let Some(reg) = &registry {
        engine.set_metrics_registry(reg.clone());
    }
    if engine.effective_batch() != window {
        println!(
            "  fused window clamped to {} (register capacity), queue depth {}",
            engine.effective_batch(),
            engine.queue_depth()
        );
    }
    let engine_report = engine.run_trace(trace.clone())?;
    let fifo_report = build(SchedPolicy::FifoDequeue)?.run_trace(trace)?;

    println!(
        "\n{:<14} {:>8} {:>9} {:>9} {:>6} {:>7} {:>8} {:>11} {:>10} {:>10} {:>10}",
        "scheduler", "offered", "admitted", "rejected", "shed", "served", "on-time",
        "goodput r/s", "p50 us", "p99 us", "p999 us"
    );
    for (name, rep) in [("slo-edf", &engine_report), ("fifo-dequeue", &fifo_report)] {
        let lat = rep.served_latencies_us();
        let (p50, p99, p999) = if lat.is_empty() {
            (f64::NAN, f64::NAN, f64::NAN)
        } else {
            let ps = fat_imc::bench_harness::percentiles(lat, &[0.50, 0.99, 0.999]);
            (ps[0], ps[1], ps[2])
        };
        println!(
            "{:<14} {:>8} {:>9} {:>9} {:>6} {:>7} {:>8} {:>11.1} {:>10.1} {:>10.1} {:>10.1}",
            name,
            rep.stats.offered,
            rep.stats.admitted,
            rep.stats.rejected,
            rep.stats.shed,
            rep.stats.served,
            rep.stats.on_time,
            rep.goodput_rps(),
            p50,
            p99,
            p999
        );
    }

    // sanity gates (the CI smoke runs this command in overload and relies
    // on a non-zero exit when they fail); under a chip-failure process
    // conservation widens to include windows lost to exhausted failover
    for (name, rep) in [("slo-edf", &engine_report), ("fifo-dequeue", &fifo_report)] {
        fat_imc::ensure!(
            rep.stats.admitted + rep.stats.rejected == rep.stats.offered
                && rep.stats.served + rep.stats.shed + rep.stats.failed == rep.stats.admitted,
            "{name}: accounting must conserve requests, got {:?}",
            rep.stats
        );
        if mtbf.is_none() {
            fat_imc::ensure!(
                rep.stats.failed == 0 && rep.failed.is_empty(),
                "{name}: no request may fail without a chip-failure process, got {:?}",
                rep.stats
            );
        }
    }
    if mtbf.is_some() {
        println!(
            "\nchip failures: slo-edf lost {} requests to exhausted failover, \
fifo-dequeue {} (all accounted, none hung)",
            engine_report.stats.failed, fifo_report.stats.failed
        );
    } else {
        // 2% tie tolerance: at underload the two schedulers serve the same
        // requests and differ only in data-dependent fused-window latencies
        // (with chip failures armed the goodput comparison is skipped: a
        // failure landing mid-window penalizes the schedulers unevenly)
        fat_imc::ensure!(
            engine_report.goodput_rps() >= 0.98 * fifo_report.goodput_rps(),
            "the SLO engine must not lose goodput to the dequeue-fusion baseline: {:.1} vs {:.1} r/s",
            engine_report.goodput_rps(),
            fifo_report.goodput_rps()
        );
    }
    println!(
        "\ngoodput: slo-edf {:.1} r/s vs fifo-dequeue {:.1} r/s ({:.2}x)",
        engine_report.goodput_rps(),
        fifo_report.goodput_rps(),
        engine_report.goodput_rps() / fifo_report.goodput_rps().max(1e-12)
    );
    if trace_out.is_some() || metrics_out.is_some() {
        println!(
            "\nstall attribution (slo-edf): {}",
            engine_report.stall_attribution().summary()
        );
        export_telemetry(trace_buf.as_ref(), registry.as_ref(), trace_out, metrics_out)?;
    }
    println!("loadgen OK");
    Ok(())
}

/// End-to-end ResNet-18 on the weight-stationary session: the geometry
/// table driven layer-by-layer through the chip with DPU BN + ReLU (and
/// the stem max pool) between layers.
fn cmd_resnet(args: &Args) -> Result<()> {
    args.allow(&[
        "batch", "input", "scale", "sparsity", "layers", "requests", "classes", "shards",
        "fidelity", "auto", "chips", "wreg", "serve",
    ])?;
    let shards = args.get_usize("shards", 1)?;
    let auto = args.get_bool("auto");
    let serve = args.get_bool("serve");
    if auto && args.get("shards").is_some() {
        fat_imc::bail!("--auto plans its own stages; drop --shards (use --chips for the budget)");
    }
    if !auto && args.get("chips").is_some() {
        fat_imc::bail!("--chips needs --auto (manual pipelines use --shards)");
    }
    if serve && !auto {
        fat_imc::bail!("--serve replays the auto plan through the hybrid server; add --auto");
    }
    let batch = args.get_usize("batch", 1)?;
    let input = args.get_usize("input", 16)?;
    let scale = args.get_usize("scale", 16)?;
    let sparsity = args.get_f64("sparsity", 0.7)?;
    let n_req = args.get_usize("requests", 4)?.max(1);
    let classes = args.get_usize("classes", 10)?;
    let geo = fat_imc::nn::resnet::resnet18_conv_layers_scaled(batch, input, scale);
    let n_layers = args.get_usize("layers", geo.len())?;
    if n_layers == 0 || n_layers > geo.len() {
        fat_imc::bail!("--layers must be 1..={}", geo.len());
    }
    // the classifier head only makes sense on the full backbone
    let head = if n_layers == geo.len() { Some(classes) } else { None };
    let spec = ModelSpec::synthetic("resnet18", &geo[..n_layers], true, sparsity, 0xE2E, head);

    println!(
        "ResNet-18 (scaled: input {input}x{input}, channels/{scale}, batch {batch}), \
{n_layers} conv layers, sparsity {:.0}%",
        spec.sparsity() * 100.0
    );
    let mut chip_cfg = ChipConfig::fat();
    if let Some(f) = fidelity_flag(args)? {
        chip_cfg.fidelity = f;
    }
    chip_cfg.wreg_entries_per_cma = args.get_usize("wreg", chip_cfg.wreg_entries_per_cma)?;
    println!("compute path: {:?} fidelity", chip_cfg.effective_fidelity());
    if auto {
        let chips = args.get_usize("chips", 2)?;
        return run_hybrid_auto(chip_cfg, spec, chips, n_req, serve);
    }
    if shards > 1 {
        return run_resnet_sharded(chip_cfg, spec, shards, n_req);
    }
    let mut session = ChipSession::new(chip_cfg, spec)?;

    let mut t = Table::new(
        "resident model (planned once, registers written once)",
        &["layer", "C", "HxW", "KN", "s", "tiles", "steps", "wreg writes"],
    );
    for (ls, pl) in session.spec().layers.iter().zip(session.model().planned_layers()) {
        let writes: u64 =
            pl.units.iter().flat_map(|u| u.tiles.iter()).map(|w| w.wreg_writes).sum();
        let tiles: usize = pl.units.iter().map(|u| u.plan.assignments.len()).sum();
        let steps: usize = pl.units.iter().map(|u| u.plan.steps).sum();
        let (_, c, h, w) = ls.op.in_geometry();
        let stride = match ls.op {
            LayerOp::Conv(l) => l.stride,
            LayerOp::GroupedConv(g) => g.stride,
            LayerOp::Gemm(_) => 1,
        };
        t.row(vec![
            ls.op.name().into(),
            format!("{c}"),
            format!("{h}x{w}"),
            format!("{}", ls.op.kn()),
            format!("{stride}"),
            format!("{tiles}"),
            format!("{steps}"),
            format!("{writes}"),
        ]);
    }
    println!("{}", t.render());
    let loading = *session.loading();
    println!(
        "one-time load: {} register writes, {:.1} us simulated",
        loading.weight_reg_writes,
        loading.weight_load_ns / 1e3
    );

    let mut rng = Rng::new(0xE2E);
    let xs: Vec<Tensor4> = (0..n_req).map(|_| session.spec().random_input(&mut rng)).collect();
    let t0 = std::time::Instant::now();
    let outs = session.run_batch(&xs)?;
    let host_s = t0.elapsed().as_secs_f64();

    let mut total = loading;
    for o in &outs {
        total.add(&o.metrics);
    }
    let compute_ns: f64 = outs.iter().map(|o| o.metrics.latency_ns).sum();
    let dpu_ns: f64 = outs.iter().map(|o| o.metrics.dpu_ns).sum();
    println!("served {n_req} requests in {host_s:.2} s host time");
    println!("  simulated compute : {:.1} us ({:.1} us DPU)", compute_ns / 1e3, dpu_ns / 1e3);
    println!(
        "  loading vs compute: {:.1} us once vs {:.1} us/request — naive reloading would add {:.1} us",
        loading.weight_load_ns / 1e3,
        compute_ns / 1e3 / n_req as f64,
        loading.weight_load_ns * (n_req as f64 - 1.0) / 1e3
    );
    println!(
        "  adds {} | skipped {} | senses {} | writes {}",
        total.adds, total.skipped, total.senses, total.writes
    );
    if let Some(logits) = &outs[0].logits {
        let row = &logits[0];
        let top = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!("  request 0 logits[0]: argmax class {top} of {}", row.len());
    }
    Ok(())
}

/// `fat workload`: serve the op IR's non-conv compute shapes — a ternary
/// transformer block (fused-QKV GEMMs with the DPU attention epilogue)
/// or a MobileNet-style depthwise/pointwise backbone (grouped convs) —
/// on the single-chip session, or through the auto-planned hybrid fabric
/// (`--auto --chips N`) and the threaded server (`--serve`), proving
/// bit-exactness against the single-chip oracle on the way.
fn cmd_workload(args: &Args) -> Result<()> {
    args.allow(&[
        "net", "seq", "dim", "heads", "ffn", "batch", "input", "width", "classes",
        "sparsity", "requests", "fidelity", "auto", "chips", "serve",
    ])?;
    let auto = args.get_bool("auto");
    let serve = args.get_bool("serve");
    if serve && !auto {
        fat_imc::bail!("--serve replays the auto plan through the hybrid server; add --auto");
    }
    if !auto && args.get("chips").is_some() {
        fat_imc::bail!("--chips needs --auto");
    }
    let sparsity = args.get_f64("sparsity", 0.6)?;
    let n_req = args.get_usize("requests", 4)?.max(1);
    let spec = match args.get_or("net", "transformer") {
        "transformer" => {
            let seq = args.get_usize("seq", 8)?;
            let dim = args.get_usize("dim", 8)?;
            let heads = args.get_usize("heads", 2)?;
            let ffn = args.get_usize("ffn", 2)?;
            ModelSpec::synthetic_transformer(seq, dim, heads, ffn, sparsity, 0xE2E)
        }
        "mobilenet" => {
            let batch = args.get_usize("batch", 1)?;
            let input = args.get_usize("input", 16)?;
            let width = args.get_usize("width", 8)?;
            let classes = args.get_usize("classes", 10)?;
            ModelSpec::synthetic_mobilenet(batch, input, width, sparsity, 0xE2E, classes)
        }
        other => fat_imc::bail!("--net must be transformer or mobilenet, got `{other}`"),
    };
    let mut chip_cfg = ChipConfig::fat();
    if let Some(f) = fidelity_flag(args)? {
        chip_cfg.fidelity = f;
    }
    let planner = chip_cfg.planner();
    println!(
        "{}: {} op-IR layers, {} ternary weights, sparsity {:.0}%",
        spec.name,
        spec.layers.len(),
        spec.weight_count(),
        spec.sparsity() * 100.0
    );
    let mut t = Table::new(
        "op IR (what the planner sees)",
        &["layer", "op", "in NxCxHxW", "KN", "wreg", "MACs"],
    );
    for ls in &spec.layers {
        let (n, c, h, w) = ls.op.in_geometry();
        let kind = match ls.op {
            LayerOp::Conv(l) => format!("conv {}x{}/s{}", l.kh, l.kw, l.stride),
            LayerOp::GroupedConv(g) => format!("grouped conv x{}", g.groups),
            LayerOp::Gemm(g) => format!("gemm {}x{}x{}", g.m, g.k, g.n),
        };
        let kind = match ls.attn {
            Some(a) => format!("{kind} +attn({})", a.heads),
            None => kind,
        };
        t.row(vec![
            ls.op.name().into(),
            kind,
            format!("{n}x{c}x{h}x{w}"),
            format!("{}", ls.op.kn()),
            format!("{}", op_wreg_footprint(&ls.op, &planner)),
            format!("{}", ls.op.macs()),
        ]);
    }
    println!("{}", t.render());
    println!("compute path: {:?} fidelity", chip_cfg.effective_fidelity());

    if auto {
        let chips = args.get_usize("chips", 2)?;
        return run_hybrid_auto(chip_cfg, spec, chips, n_req, serve);
    }

    // single-chip weight-stationary serving
    let mut session = ChipSession::new(chip_cfg, spec.clone())?;
    let loading = *session.loading();
    println!(
        "one-time load: {} register writes, {:.1} us simulated",
        loading.weight_reg_writes,
        loading.weight_load_ns / 1e3
    );
    let mut rng = Rng::new(0xE2E);
    let xs: Vec<Tensor4> = (0..n_req).map(|_| spec.random_input(&mut rng)).collect();
    let t0 = std::time::Instant::now();
    let outs = session.run_batch(&xs)?;
    let host_s = t0.elapsed().as_secs_f64();
    let compute_ns: f64 = outs.iter().map(|o| o.metrics.latency_ns).sum();
    let dpu_ns: f64 = outs.iter().map(|o| o.metrics.dpu_ns).sum();
    println!("served {n_req} requests in {host_s:.2} s host time");
    println!(
        "  simulated compute : {:.1} us ({:.1} us DPU incl. attention)",
        compute_ns / 1e3,
        dpu_ns / 1e3
    );
    println!(
        "  per-request weight-register writes: {} (weights are resident)",
        outs.iter().map(|o| o.metrics.weight_reg_writes).sum::<u64>()
    );
    if let Some(logits) = &outs[0].logits {
        let row = &logits[0];
        let top = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!("  request 0 logits[0]: argmax class {top} of {}", row.len());
    }
    Ok(())
}

/// `fat resnet --shards N`: cut the model at layer boundaries into N
/// footprint-balanced shards, serve it as a chip pipeline, charge the
/// inter-chip link at every boundary, and prove bit-exactness against the
/// single-chip session (when one chip can hold the whole model).
fn run_resnet_sharded(cfg: ChipConfig, spec: ModelSpec, shards: usize, n_req: usize) -> Result<()> {
    let hw = HwParams::default();
    let plan = ShardPlan::partition(&spec, &cfg, shards)?;

    let mut t = Table::new(
        &format!(
            "shard plan over {shards} chips ({} register entries per chip)",
            plan.capacity
        ),
        &["shard", "layers", "count", "wreg footprint"],
    );
    for (i, (&(a, b), &fp)) in plan.ranges.iter().zip(&plan.footprints).enumerate() {
        t.row(vec![
            format!("{}", i + 1),
            format!("{}..{}", spec.layers[a].op.name(), spec.layers[b - 1].op.name()),
            format!("{}", b - a),
            format!("{fp}"),
        ]);
    }
    println!("{}", t.render());

    let mut pipe = PipelineSession::new(cfg, spec.clone(), shards, hw)?;
    let loadings = pipe.shard_loadings();
    let shard_writes: u64 = loadings.iter().map(|m| m.weight_reg_writes).sum();
    println!(
        "per-shard one-time loads: {} register writes total across {shards} chips",
        shard_writes
    );

    // the single-chip oracle, when the whole model fits one chip
    let mut oracle = match ChipSession::new(cfg, spec.clone()) {
        Ok(s) => Some(s),
        Err(_) => {
            println!("(model exceeds one chip's register capacity; single-chip oracle skipped)");
            None
        }
    };
    if let Some(o) = &oracle {
        fat_imc::ensure!(
            shard_writes == o.loading().weight_reg_writes,
            "register-write conservation broken: shards {} vs single chip {}",
            shard_writes,
            o.loading().weight_reg_writes
        );
        println!(
            "register-write conservation: {} writes sharded == {} unsharded",
            shard_writes,
            o.loading().weight_reg_writes
        );
    }

    let mut rng = Rng::new(0xE2E);
    let mut xfer_ns_total = 0.0f64;
    let mut xfer_bytes_total = 0u64;
    // steady-state cost model, averaged over all requests (per-request
    // latencies vary with activation sparsity)
    let mut serial_sum_ns = 0.0f64;
    let mut interval_sum_ns = 0.0f64;
    for i in 0..n_req {
        let x = spec.random_input(&mut rng);
        let po = pipe.infer(&x)?;
        if let Some(o) = oracle.as_mut() {
            let want = o.infer(&x)?;
            fat_imc::ensure!(
                po.out.features.data == want.features.data && po.out.logits == want.logits,
                "request {i}: pipelined output diverged from the single-chip oracle"
            );
        }
        xfer_ns_total += po.out.metrics.xfer_ns;
        xfer_bytes_total += po.out.metrics.xfer_bytes;
        serial_sum_ns += po.serial_ns();
        interval_sum_ns += po.issue_interval_ns();
        println!(
            "  request {i}: {:.1} us compute across {shards} chips, {:.2} us on the link \
({} bytes over {} legs)",
            po.out.metrics.compute_ns() / 1e3,
            po.out.metrics.xfer_ns / 1e3,
            po.out.metrics.xfer_bytes,
            po.xfer_legs_ns.len()
        );
    }
    if oracle.is_some() {
        println!("pipeline outputs bit-identical to the single-chip oracle across {n_req} requests");
    }
    println!(
        "inter-chip transfer total: {xfer_bytes_total} bytes, {:.2} us",
        xfer_ns_total / 1e3
    );
    let serial_ns = serial_sum_ns / n_req as f64;
    let interval_ns = interval_sum_ns / n_req as f64;
    if interval_ns > 0.0 {
        println!(
            "steady-state pipeline interval {:.1} us vs serial latency {:.1} us -> {} \
issue-rate speedup (mean of {n_req} requests)",
            interval_ns / 1e3,
            serial_ns / 1e3,
            ratio(serial_ns / interval_ns)
        );
    }
    Ok(())
}

/// Render a hybrid plan's stage table.
fn print_hybrid_plan(spec: &ModelSpec, plan: &HybridPlan, chips_asked: usize) {
    let mut t = Table::new(
        &format!(
            "auto hybrid plan: {chips_asked} chip(s) requested, {} used \
({} register entries per chip)",
            plan.chips(),
            plan.capacity
        ),
        &["stage", "layers", "ways", "max chip wreg", "est latency (us)"],
    );
    for (i, st) in plan.stages.iter().enumerate() {
        let (a, b) = st.range;
        t.row(vec![
            format!("{}", i + 1),
            format!("{}..{}", spec.layers[a].op.name(), spec.layers[b - 1].op.name()),
            format!("{}", st.ways),
            format!("{}", st.chip_footprints.iter().max().expect("at least one chip")),
            format!("{:.1}", st.est_ns / 1e3),
        ]);
    }
    println!("{}", t.render());
    println!(
        "estimated issue interval: {:.1} us (bottleneck stage)",
        plan.est_interval_ns() / 1e3
    );
}

/// `fat resnet --auto --chips N` / `fat workload --auto --chips N`:
/// latency-balanced hybrid serving — the auto-planner composes
/// layer-boundary stages with per-layer KN splits, loads the model across
/// the chosen chips, and proves bit-exactness against a
/// capacity-unlimited single-chip oracle.  Spec-generic: any op-IR model
/// (conv, grouped conv, GEMM + attention) goes through unchanged.
fn run_hybrid_auto(
    cfg: ChipConfig,
    spec: ModelSpec,
    chips: usize,
    n_req: usize,
    serve: bool,
) -> Result<()> {
    let hw = HwParams::default();
    let plan = plan_auto(&cfg, &spec, chips, &hw)?;
    print_hybrid_plan(&spec, &plan, chips);

    let mut sess = TensorParallelSession::new(cfg, spec.clone(), plan.clone(), hw)?;
    // the oracle: same array geometry, register capacity lifted (capacity
    // is only an admission gate, never a value change)
    let mut big = cfg;
    big.wreg_entries_per_cma = big.wreg_entries_per_cma.max(1 << 20);
    let mut oracle = ChipSession::new(big, spec.clone())?;
    fat_imc::ensure!(
        sess.loading_total().weight_reg_writes == oracle.loading().weight_reg_writes,
        "register-write conservation broken across KN slices"
    );
    println!(
        "register-write conservation: {} writes across all slices == unsplit total",
        oracle.loading().weight_reg_writes
    );

    let mut rng = Rng::new(0xE2E);
    let mut xfer_bytes = 0u64;
    let mut xfer_ns = 0.0f64;
    let mut interval_sum = 0.0f64;
    let mut serial_sum = 0.0f64;
    for i in 0..n_req {
        let x = spec.random_input(&mut rng);
        let ho = sess.infer(&x)?;
        let want = oracle.infer(&x)?;
        fat_imc::ensure!(
            ho.outs[0].features.data == want.features.data && ho.outs[0].logits == want.logits,
            "request {i}: hybrid output diverged from the single-chip oracle"
        );
        let m = &ho.outs[0].metrics;
        xfer_bytes += m.xfer_bytes;
        xfer_ns += m.xfer_ns;
        interval_sum += ho.issue_interval_ns();
        // the honest serial baseline is the oracle's measured latency: a
        // TP stage's latency is its slowest slice + gather time, which
        // no single chip pays, so summing hybrid stages would misstate it
        serial_sum += want.metrics.latency_ns;
        println!(
            "  request {i}: {:.1} us compute, {:.2} us on the link ({} bytes over {} hops)",
            m.compute_ns() / 1e3,
            m.xfer_ns / 1e3,
            m.xfer_bytes,
            m.xfer_legs
        );
    }
    println!(
        "hybrid outputs bit-identical to the single-chip oracle across {n_req} requests"
    );
    println!(
        "all-gather + boundary transfer total: {xfer_bytes} bytes, {:.2} us",
        xfer_ns / 1e3
    );
    if interval_sum > 0.0 {
        println!(
            "steady-state issue interval {:.1} us vs single-chip latency {:.1} us -> {} \
issue-rate speedup (mean of {n_req} requests)",
            interval_sum / n_req as f64 / 1e3,
            serial_sum / n_req as f64 / 1e3,
            ratio(serial_sum / interval_sum)
        );
    }
    if serve {
        // the same plan on the threaded server: stages on their own
        // threads, TP slices fanning out inside each stage
        println!("replaying the plan through the hybrid server ({n_req} requests)...");
        let server = InferenceServer::start_with(
            cfg,
            ServingMode::Hybrid { plan, max_batch: 1 },
            spec.clone(),
        )?;
        let mut rng = Rng::new(0x5E12);
        let xs: Vec<_> = (0..n_req).map(|_| spec.random_input(&mut rng)).collect();
        let t0 = std::time::Instant::now();
        for (id, x) in xs.iter().enumerate() {
            server.submit(Request { id: id as u64, x: x.clone() })?;
        }
        let mut responses =
            server.collect_timeout(n_req, std::time::Duration::from_secs(600))?;
        let wall = t0.elapsed().as_secs_f64();
        responses.sort_by_key(|r| r.id);
        for r in &responses {
            let want = oracle.infer(&xs[r.id as usize])?;
            fat_imc::ensure!(
                r.features.data == want.features.data && r.logits == want.logits,
                "served request {} diverged from the single-chip oracle",
                r.id
            );
        }
        server.shutdown();
        println!(
            "  served {n_req} requests in {wall:.3}s ({:.1} req/s), bit-identical to the oracle",
            n_req as f64 / wall
        );
    }
    Ok(())
}

/// `fat plan`: profile per-layer latencies on the simulator, compare the
/// footprint-balanced and latency-balanced pure-pipeline cuts, and print
/// the latency-balanced hybrid (shards x kn-splits) plan for a target
/// chip count.
fn cmd_plan(args: &Args) -> Result<()> {
    args.allow(&[
        "chips", "wreg", "batch", "input", "scale", "sparsity", "layers", "classes",
    ])?;
    let chips = args.get_usize("chips", 2)?;
    let batch = args.get_usize("batch", 1)?;
    let input = args.get_usize("input", 16)?;
    let scale = args.get_usize("scale", 16)?;
    let sparsity = args.get_f64("sparsity", 0.7)?;
    let classes = args.get_usize("classes", 10)?;
    let geo = fat_imc::nn::resnet::resnet18_conv_layers_scaled(batch, input, scale);
    let n_layers = args.get_usize("layers", geo.len())?;
    if n_layers == 0 || n_layers > geo.len() {
        fat_imc::bail!("--layers must be 1..={}", geo.len());
    }
    let head = if n_layers == geo.len() { Some(classes) } else { None };
    let spec = ModelSpec::synthetic("resnet18", &geo[..n_layers], true, sparsity, 0xE2E, head);
    let mut cfg = ChipConfig::fat();
    cfg.wreg_entries_per_cma = args.get_usize("wreg", cfg.wreg_entries_per_cma)?;
    let hw = HwParams::default();
    let planner = cfg.planner();

    // per-layer profile: register footprint, minimum feasible KN split,
    // and the simulated per-chip latency at that width
    let prof = profile_layers(&cfg, &spec, &hw)?;
    let mut t = Table::new(
        &format!(
            "per-layer profile ({} register entries per chip)",
            cfg.wreg_capacity()
        ),
        &["layer", "KN", "wreg", "min ways", "latency (us)"],
    );
    let mut lat_weights = Vec::with_capacity(prof.len());
    for (ls, &(ways, ns)) in spec.layers.iter().zip(&prof) {
        let fp = op_wreg_footprint(&ls.op, &planner);
        lat_weights.push(ns.max(1.0) as u64);
        t.row(vec![
            ls.op.name().into(),
            format!("{}", ls.op.kn()),
            format!("{fp}"),
            format!("{ways}"),
            format!("{:.1}", ns / 1e3),
        ]);
    }
    println!("{}", t.render());

    // the two pure-pipeline objectives, where layer-boundary sharding is
    // feasible at all
    if chips <= spec.layers.len() {
        let by_fp = ShardPlan::partition(&spec, &cfg, chips);
        let by_lat = ShardPlan::partition_weighted(&spec, &cfg, chips, &lat_weights);
        match (by_fp, by_lat) {
            (Ok(fp_plan), Ok(lat_plan)) => {
                let stage_ns = |r: &(usize, usize)| -> f64 {
                    prof[r.0..r.1].iter().map(|&(_, ns)| ns).sum()
                };
                let b_fp =
                    fp_plan.ranges.iter().map(stage_ns).fold(0.0, f64::max);
                let b_lat =
                    lat_plan.ranges.iter().map(stage_ns).fold(0.0, f64::max);
                println!(
                    "pure pipeline over {chips} chips: footprint-balanced bottleneck \
{:.1} us vs latency-balanced {:.1} us",
                    b_fp / 1e3,
                    b_lat / 1e3
                );
            }
            _ => println!(
                "pure layer-boundary pipeline infeasible at {chips} chip(s) (oversized \
layer or capacity) — the hybrid plan below is required"
            ),
        }
    }

    let plan = plan_auto(&cfg, &spec, chips, &hw)?;
    print_hybrid_plan(&spec, &plan, chips);
    Ok(())
}
