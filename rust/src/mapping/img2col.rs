//! Img2Col (Fig. 8): convolution -> GEMM.
//!
//! The activation tensor (N, C, H, W) becomes an (N*I, J) matrix with
//! I = OH*OW output pixels and J = C*KH*KW reduction taps; column i of the
//! GEMM ("memory column") is one output pixel's receptive field, and the
//! J dimension maps to memory rows for sequential addition.  Matches the
//! python oracle `compile.kernels.ref.img2col_ref` ordering exactly
//! (batch-major columns; J ordered (c, kh, kw)).

use crate::nn::resnet::ConvLayer;
use crate::nn::tensor::Tensor4;

/// Img2Col activation matrix: `get(col, j)` with `col` in `0..n*i`.
#[derive(Debug, Clone)]
pub struct Img2ColMatrix {
    /// Columns: N * I (batch-major, then row-major output pixels).
    pub cols: usize,
    /// Rows: J = C * KH * KW.
    pub j: usize,
    /// Row-major by column: `data[col * j + jj]`.
    pub data: Vec<f32>,
}

impl Img2ColMatrix {
    /// An empty matrix whose buffer can be (re)filled by [`img2col_into`]
    /// — the session's per-request scratch, allocated once.
    pub fn empty() -> Self {
        Self { cols: 0, j: 0, data: Vec::new() }
    }

    #[inline]
    pub fn get(&self, col: usize, jj: usize) -> f32 {
        self.data[col * self.j + jj]
    }

    /// Column slice (one output pixel's receptive field).
    pub fn column(&self, col: usize) -> &[f32] {
        &self.data[col * self.j..(col + 1) * self.j]
    }
}

/// Perform the Img2Col transform for a conv layer geometry.
pub fn img2col(x: &Tensor4, layer: &ConvLayer) -> Img2ColMatrix {
    let mut out = Img2ColMatrix::empty();
    img2col_into(x, layer, &mut out);
    out
}

/// Img2Col into a reusable scratch matrix: the buffer is resized (keeping
/// its capacity) instead of reallocated, so a serving loop that calls this
/// per request per layer allocates only on the first, largest layer.
/// Every cell of the `cols x j` extent is overwritten, so stale contents
/// of a recycled buffer never leak into the result.
pub fn img2col_into(x: &Tensor4, layer: &ConvLayer, out: &mut Img2ColMatrix) {
    assert_eq!(x.n, layer.n);
    assert_eq!(x.c, layer.c);
    assert_eq!(x.h, layer.h);
    assert_eq!(x.w, layer.w);
    let (oh, ow) = (layer.oh(), layer.ow());
    let j = layer.j_dim();
    let cols = layer.n * oh * ow;
    out.cols = cols;
    out.j = j;
    // no clear(): resize only touches the delta, the fill below covers all
    out.data.resize(cols * j, 0.0);
    let data = &mut out.data;
    let (s, p) = (layer.stride as isize, layer.pad as isize);
    for n in 0..layer.n {
        for out_h in 0..oh {
            for out_w in 0..ow {
                let col = (n * oh + out_h) * ow + out_w;
                let base = col * j;
                let mut jj = 0;
                // NOTE (perf pass): a memcpy fast path for fully-in-bounds
                // kw runs was tried and *reverted* — at kw=3 the bounds
                // branch costs more than the copy saves (390us vs 350us).
                for c in 0..layer.c {
                    for i in 0..layer.kh {
                        for k in 0..layer.kw {
                            let hh = out_h as isize * s + i as isize - p;
                            let ww = out_w as isize * s + k as isize - p;
                            data[base + jj] = x.get_padded(n, c, hh, ww);
                            jj += 1;
                        }
                    }
                }
            }
        }
    }
}

/// GEMM between the Img2Col matrix and one unrolled ternary filter —
/// the reference for the in-array sparse dot product.
pub fn gemm_column(ax: &Img2ColMatrix, filter_flat: &[i8]) -> Vec<f32> {
    assert_eq!(filter_flat.len(), ax.j);
    (0..ax.cols)
        .map(|col| {
            let x = ax.column(col);
            let mut acc = 0.0f32;
            for (xv, &w) in x.iter().zip(filter_flat) {
                if w != 0 {
                    acc += w as f32 * xv;
                }
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::{conv2d_ternary, TernaryFilter};
    use crate::testutil::{prop_check, Rng};

    fn small_layer(c: usize, h: usize, kh: usize, s: usize, p: usize, kn: usize) -> ConvLayer {
        ConvLayer { name: "t", n: 2, c, h, w: h, kn, kh, kw: kh, stride: s, pad: p }
    }

    #[test]
    fn img2col_shape_layer10() {
        let l = crate::nn::resnet::resnet18_layer10();
        let x = Tensor4::zeros(l.n, l.c, l.h, l.w);
        let m = img2col(&x, &l);
        assert_eq!(m.cols, 5 * 196); // N * I = 980
        assert_eq!(m.j, 1152);
    }

    #[test]
    fn img2col_identity_1x1() {
        // 1x1 kernel, stride 1, no pad: Ax[col][c] == x[n][c][h][w]
        let l = small_layer(3, 4, 1, 1, 0, 1);
        let mut x = Tensor4::zeros(2, 3, 4, 4);
        let mut rng = Rng::new(2);
        x.fill_random_ints(&mut rng, 0, 9);
        let m = img2col(&x, &l);
        assert_eq!(m.j, 3);
        for n in 0..2 {
            for h in 0..4 {
                for w in 0..4 {
                    let col = (n * 4 + h) * 4 + w;
                    for c in 0..3 {
                        assert_eq!(m.get(col, c), x.get(n, c, h, w));
                    }
                }
            }
        }
    }

    #[test]
    fn property_img2col_gemm_equals_direct_conv() {
        prop_check(
            "img2col + gemm == direct conv",
            12,
            0x1236,
            |rng| {
                let c = rng.range(1, 4);
                let h = rng.range(4, 9);
                let s = rng.range(1, 3);
                let p = rng.range(0, 2);
                let mut x = Tensor4::zeros(2, c, h, h);
                x.fill_random_ints(rng, -5, 6);
                let w = rng.ternary_vec(3 * c * 9, 0.4);
                (small_layer(c, h, 3, s, p, 3), x, w)
            },
            |(l, x, w)| {
                if l.h + 2 * l.pad < l.kh {
                    return Ok(());
                }
                let f = TernaryFilter::new(3, l.c, 3, 3, w.clone());
                let direct = conv2d_ternary(x, &f, l.stride, l.pad);
                let m = img2col(x, l);
                for kn in 0..3 {
                    let got = gemm_column(&m, &f.filter_flat(kn));
                    let (oh, ow) = (l.oh(), l.ow());
                    for n in 0..l.n {
                        for h in 0..oh {
                            for wo in 0..ow {
                                let col = (n * oh + h) * ow + wo;
                                let want = direct.get(n, kn, h, wo);
                                if (got[col] - want).abs() > 1e-4 {
                                    return Err(format!(
                                        "kn={kn} n={n} ({h},{wo}): {} vs {want}",
                                        got[col]
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scratch_reuse_matches_fresh_allocation_across_layer_sizes() {
        // big -> small -> big through ONE scratch buffer must equal the
        // allocating path bit for bit (stale tail contents must not leak).
        let layers = [
            small_layer(3, 8, 3, 1, 1, 2),
            small_layer(1, 4, 3, 1, 0, 2),
            small_layer(2, 6, 3, 2, 1, 2),
        ];
        let mut rng = Rng::new(0x5C4);
        let mut scratch = Img2ColMatrix::empty();
        for l in &layers {
            let mut x = Tensor4::zeros(l.n, l.c, l.h, l.w);
            x.fill_random_ints(&mut rng, 0, 9);
            let fresh = img2col(&x, l);
            img2col_into(&x, l, &mut scratch);
            assert_eq!(scratch.cols, fresh.cols);
            assert_eq!(scratch.j, fresh.j);
            assert_eq!(scratch.data, fresh.data, "layer {}", l.name);
        }
    }

    #[test]
    fn stride_reduces_columns() {
        let l1 = small_layer(1, 8, 3, 1, 1, 1);
        let l2 = small_layer(1, 8, 3, 2, 1, 1);
        let x = Tensor4::zeros(2, 1, 8, 8);
        assert_eq!(img2col(&x, &l1).cols, 2 * 64);
        assert_eq!(img2col(&x, &l2).cols, 2 * 16);
    }
}
