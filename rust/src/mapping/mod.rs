//! Data mapping — §III-C of the paper.
//!
//! FAT's mapping scenario is unusual: *activations* go into the memory
//! arrays and *weights* go into the SACU registers in the controller, so
//! neither ReRAM-crossbar weight mapping nor STT-CiM's both-in-array
//! mapping applies.  This module provides:
//!
//! - [`img2col`]: the Img2Col transform (Fig. 8) that turns convolution
//!   into the GEMM the memory columns can parallelize;
//! - [`schemes`]: the analytic cost model of Table VII for Direct-OS and
//!   the four Img2Col mappings (OS / IS / WS / CS), scaled to the chip's
//!   4096 CMAs (Table VIII);
//! - [`planner`]: the grid-based assignment of activation sub-arrays to
//!   CMAs (Fig. 9), with the CS interval rows and the J-priority
//!   processing sequence.

pub mod img2col;
pub mod planner;
pub mod schemes;

pub use img2col::{img2col, Img2ColMatrix};
pub use planner::{GridPlan, PlannerConfig};
pub use schemes::{evaluate_mapping, HwParams, MappingCost, MappingKind};
