//! The grid-based CMA assignment of Fig. 9.
//!
//! The whole Img2Col activation matrix (N*I columns x J rows) is cut into
//! sub-arrays of CMA size (MW columns x MH operands) and assigned to the
//! available CMAs.  When the matrix exceeds the chip, the planner emits
//! *steps* (Fig. 9 (b)/(c)) and prioritizes the J dimension so immediate
//! accumulation results are reused before activations are evicted.
//!
//! Op-IR note: the planner is op-kind agnostic — it only ever sees a
//! plain [`ConvLayer`], one per execution unit of a
//! `nn::ops::LayerOp` (`coordinator::session` plans a grouped conv as
//! `groups` independent unit grids).  Two degenerate geometries are
//! load-bearing: a lowered GEMM (`nn::ops::GemmLayer::lower`) is a
//! 1x1/s1/p0 conv whose Img2Col matrix *is* the activation matrix
//! (N*I = b*m columns, J = k rows), and a depthwise unit has `kn = 1`
//! with a tiny J (`cg*kh*kw`), so its grid degenerates to many small
//! single-filter plans whose register footprints are summed per unit by
//! `coordinator::session::op_wreg_footprint`.  Neither shape needs
//! special cases here — the tiling math below already covers them.

use crate::nn::resnet::ConvLayer;

/// Planner configuration.
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    /// Operands one CMA column holds (effective MH: 64 dense, 32 CS).
    pub mh: usize,
    /// Columns per CMA.
    pub mw: usize,
    /// CMAs available.
    pub cmas: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self { mh: 32, mw: 256, cmas: 4096 }
    }
}

impl PlannerConfig {
    /// Column tiles a layer's Img2Col matrix occupies (its `N*I` columns
    /// cut into MW-wide groups).  Every column tile keeps its own copy of
    /// the SACU weight registers, so this is the multiplier in a layer's
    /// resident register footprint — and it is independent of KN, which is
    /// what makes a filter-dimension (KN) split's footprint exactly linear
    /// in the slice width (see `coordinator::tensor_parallel`).
    pub fn col_tiles(&self, layer: &ConvLayer) -> usize {
        (layer.n * layer.i_dim()).div_ceil(self.mw)
    }
}

/// One tile of the activation matrix assigned to a CMA at a given step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    /// Execution step (0-based; steps run sequentially).
    pub step: usize,
    /// CMA index within the step.
    pub cma: usize,
    /// Column range [col0, col1) of the Img2Col matrix.
    pub col0: usize,
    pub col1: usize,
    /// J (reduction) range [j0, j1).
    pub j0: usize,
    pub j1: usize,
}

/// A complete grid plan for one layer.
#[derive(Debug, Clone)]
pub struct GridPlan {
    pub cfg: PlannerConfig,
    /// Tiles in execution order.
    pub assignments: Vec<Assignment>,
    /// Tiles along the J (rows) and column axes.
    pub j_tiles: usize,
    pub col_tiles: usize,
    pub steps: usize,
}

impl GridPlan {
    /// Plan a layer: tile the (N*I) x J activation matrix onto the CMAs,
    /// walking J first (Fig. 9: "We prioritize the J dimension to reuse
    /// the immediate accumulation results").
    pub fn plan(layer: &ConvLayer, cfg: PlannerConfig) -> Self {
        let total_cols = layer.n * layer.i_dim();
        let j = layer.j_dim();
        let j_tiles = j.div_ceil(cfg.mh);
        let col_tiles = total_cols.div_ceil(cfg.mw);

        let mut assignments = Vec::with_capacity(j_tiles * col_tiles);
        let mut step = 0usize;
        let mut cma_in_step = 0usize;
        // J-major order: finish a full column-group's reduction chain
        // before moving to the next columns.
        for ct in 0..col_tiles {
            for jt in 0..j_tiles {
                if cma_in_step == cfg.cmas {
                    step += 1;
                    cma_in_step = 0;
                }
                assignments.push(Assignment {
                    step,
                    cma: cma_in_step,
                    col0: ct * cfg.mw,
                    col1: ((ct + 1) * cfg.mw).min(total_cols),
                    j0: jt * cfg.mh,
                    j1: ((jt + 1) * cfg.mh).min(j),
                });
                cma_in_step += 1;
            }
        }
        Self { cfg, assignments, j_tiles, col_tiles, steps: step + 1 }
    }

    /// All tiles covering a given column group (one reduction chain).
    pub fn chain_for_columns(&self, col0: usize) -> Vec<&Assignment> {
        self.assignments.iter().filter(|a| a.col0 == col0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet::{resnet18_layer10, twn_cnn_layers};
    use crate::testutil::prop_check;

    #[test]
    fn layer10_fits_one_step_on_4096_cmas() {
        // 980 cols -> 4 col tiles; J=1152 / 32 -> 36 j tiles; 144 CMAs.
        let plan = GridPlan::plan(&resnet18_layer10(), PlannerConfig::default());
        assert_eq!(plan.col_tiles, 4);
        assert_eq!(plan.j_tiles, 36);
        assert_eq!(plan.assignments.len(), 144);
        assert_eq!(plan.steps, 1);
    }

    #[test]
    fn col_tiles_helper_matches_the_plan() {
        let layer = resnet18_layer10();
        let cfg = PlannerConfig::default();
        let plan = GridPlan::plan(&layer, cfg);
        assert_eq!(cfg.col_tiles(&layer), plan.col_tiles);
        // independent of KN: slicing the filter dimension cannot change it
        let mut sliced = layer;
        sliced.kn = 7;
        assert_eq!(cfg.col_tiles(&sliced), plan.col_tiles);
    }

    #[test]
    fn small_chip_needs_multiple_steps() {
        // Fig. 9 (c): three CMAs -> six steps for eight tiles... our
        // geometry: force cmas=3 and check steps = ceil(tiles/3).
        let layer = twn_cnn_layers(4)[1];
        let cfg = PlannerConfig { mh: 32, mw: 256, cmas: 3 };
        let plan = GridPlan::plan(&layer, cfg);
        let tiles = plan.assignments.len();
        assert_eq!(plan.steps, tiles.div_ceil(3));
    }

    #[test]
    fn property_every_cell_covered_exactly_once() {
        prop_check(
            "grid plan covers the matrix exactly once",
            15,
            0x9121,
            |rng| {
                let layer = crate::nn::resnet::ConvLayer {
                    name: "p",
                    n: rng.range(1, 4),
                    c: rng.range(1, 40),
                    h: rng.range(4, 20),
                    w: rng.range(4, 20),
                    kn: 8,
                    kh: 3,
                    kw: 3,
                    stride: rng.range(1, 3),
                    pad: 1,
                };
                let cfg = PlannerConfig { mh: rng.range(8, 64), mw: rng.range(32, 257), cmas: rng.range(2, 64) };
                (layer, cfg)
            },
            |(layer, cfg)| {
                if layer.h + 2 < 3 {
                    return Ok(());
                }
                let plan = GridPlan::plan(layer, *cfg);
                let total_cols = layer.n * layer.i_dim();
                let j = layer.j_dim();
                let mut covered = vec![0u8; total_cols * j];
                for a in &plan.assignments {
                    for c in a.col0..a.col1 {
                        for jj in a.j0..a.j1 {
                            covered[c * j + jj] += 1;
                        }
                    }
                }
                if covered.iter().all(|&v| v == 1) {
                    Ok(())
                } else {
                    let bad = covered.iter().position(|&v| v != 1).unwrap();
                    Err(format!("cell {bad} covered {} times", covered[bad]))
                }
            },
        );
    }

    #[test]
    fn j_major_order_keeps_chains_contiguous() {
        // All j-tiles of a column group must appear consecutively so the
        // reduction chain reuses partial sums (J-priority of Fig. 9).
        let plan = GridPlan::plan(&resnet18_layer10(), PlannerConfig::default());
        let mut last_col0 = None;
        let mut seen_cols = std::collections::HashSet::new();
        for a in &plan.assignments {
            if last_col0 != Some(a.col0) {
                assert!(
                    seen_cols.insert(a.col0),
                    "column group {} revisited non-contiguously",
                    a.col0
                );
                last_col0 = Some(a.col0);
            }
        }
    }

    #[test]
    fn chain_query_returns_full_reduction() {
        let plan = GridPlan::plan(&resnet18_layer10(), PlannerConfig::default());
        let chain = plan.chain_for_columns(0);
        assert_eq!(chain.len(), plan.j_tiles);
        // chain covers all of J
        let covered: usize = chain.iter().map(|a| a.j1 - a.j0).sum();
        assert_eq!(covered, 1152);
    }
}
