//! The mapping cost model — Tables VII & VIII of the paper.
//!
//! Five mappings are compared on the same layer and the same 4096-CMA
//! device: Direct-OS (output-stationary direct convolution) and the four
//! Img2Col mappings (OS / IS / WS / CS).  The model follows Table VII's
//! formulas, scaled to the available CMAs ("waves"), with time derived
//! from the array constants:
//!
//! - activation loading: `times x rows_per_load x op_bits x t_write`
//!   (row-stripe writes, all CMAs and columns in parallel; the CS interval
//!   layout halves the rows per load);
//! - weight loading: 2-bit register-file writes in the controller;
//! - compute: Table VII step counts, where one step is a pipelined
//!   accumulation addition.  Consecutive bit-serial additions in an
//!   accumulation chain overlap (bit 0 of add k+1 only needs bit 0 of add
//!   k), so a steady-state step costs ~3 bit cycles rather than a full
//!   `acc_bits` cycles — calibrated against Table VIII's compute times.

use crate::addition::AdditionScheme;
use crate::circuit::calibration::ArrayTiming;
use crate::nn::resnet::ConvLayer;

/// The five mappings of Table VII.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MappingKind {
    DirectOs,
    Img2ColOs,
    Img2ColIs,
    Img2ColWs,
    Img2ColCs,
}

impl MappingKind {
    pub const ALL: [MappingKind; 5] = [
        MappingKind::DirectOs,
        MappingKind::Img2ColOs,
        MappingKind::Img2ColIs,
        MappingKind::Img2ColWs,
        MappingKind::Img2ColCs,
    ];

    pub fn name(self) -> &'static str {
        match self {
            MappingKind::DirectOs => "Direct-OS",
            MappingKind::Img2ColOs => "Img2Col-OS",
            MappingKind::Img2ColIs => "Img2Col-IS",
            MappingKind::Img2ColWs => "Img2Col-WS",
            MappingKind::Img2ColCs => "Img2Col-CS",
        }
    }
}

/// Device parameters (Table VIII footing: MH=64, MW=256, 4096 CMAs).
#[derive(Debug, Clone, Copy)]
pub struct HwParams {
    /// Operands one memory column stores (512 rows / 8-bit = 64).
    pub mh: usize,
    /// Memory columns per CMA.
    pub mw: usize,
    /// CMAs on the chip.
    pub cmas: usize,
    /// Activation bit width.
    pub op_bits: u32,
    /// SACU weight-register write time per filter-row load, ns.
    pub t_reg_ns: f64,
    /// Inter-chip link bandwidth, bytes per ns (1 byte/ns = 1 GB/s).
    /// Charged on the quantized activation tensor at every shard boundary
    /// of a pipelined model (see `coordinator::sharding`).
    pub link_bytes_per_ns: f64,
    /// Inter-chip link hop latency, ns, paid once per transfer leg.
    pub link_latency_ns: f64,
    /// Inter-chip link bit-error rate: each bit of the transported 8-bit
    /// activation payload flips independently with this probability at
    /// every shard boundary (the error model a single chip never sees —
    /// see `coordinator::reliability`).  0.0 (the default) is an ideal
    /// link, and leaves every transfer byte-identical.
    pub link_ber: f64,
    /// Root seed of the deterministic link-corruption streams; each
    /// pipeline stage derives its own stream from (seed, stage index).
    pub link_fault_seed: u64,
    /// SECDED error correction on the link: every 64-bit payload flit
    /// carries 8 Hamming check bits (a (72,64) code), so single-bit flips
    /// per flit are corrected at the receiver and only multi-flip flits
    /// corrupt the payload — at a 12.5% wire overhead charged on every
    /// transfer leg (see [`Self::wire_bytes`] and
    /// `coordinator::session::QuantActivations::inject_link_faults`).
    pub link_ecc: bool,
}

impl Default for HwParams {
    fn default() -> Self {
        Self {
            mh: 64,
            mw: 256,
            cmas: 4096,
            op_bits: 8,
            t_reg_ns: 0.17,
            // a 128 Gb/s SerDes-class chip-to-chip link with a short hop
            link_bytes_per_ns: 16.0,
            link_latency_ns: 20.0,
            link_ber: 0.0,
            link_fault_seed: 0,
            link_ecc: false,
        }
    }
}

impl HwParams {
    /// Bytes a transfer leg actually moves for `payload` payload bytes:
    /// with SECDED link ECC armed, every 64-bit flit (8 payload bytes)
    /// carries one extra check byte — `ceil(payload / 8)` bytes of
    /// overhead, 12.5% on flit-aligned payloads.  Without ECC the wire
    /// carries the payload verbatim.
    pub fn wire_bytes(&self, payload: u64) -> u64 {
        if self.link_ecc {
            payload + payload.div_ceil(8)
        } else {
            payload
        }
    }
}

/// Cost-model output for one (mapping, layer) pair.
#[derive(Debug, Clone)]
pub struct MappingCost {
    pub kind: MappingKind,
    /// Activation operands written per load x number of loads.
    pub x_load_times: u64,
    pub x_writes: u64,
    /// Weight loads (SACU register refills) and register writes.
    pub w_load_times: u64,
    pub w_writes: u64,
    /// Columns usable in parallel per CMA.
    pub parallel_cols: usize,
    /// CMAs a full problem instance occupies (before wave scaling).
    pub occupied_cmas: u64,
    /// Sequential waves after scaling to the available CMAs.
    pub waves: u64,
    /// Memory utilization of the activation storage.
    pub utilization: f64,
    pub x_load_ns: f64,
    pub w_load_ns: f64,
    pub compute_ns: f64,
    /// Worst-case writes to a single cell relative to one activation load
    /// (the Table VIII endurance column: 64x for fixed accumulators, 1x
    /// for the CS interval rotation).
    pub max_cell_write_factor: u32,
    /// Activation-loading energy, pJ.
    pub load_energy_pj: f64,
    /// In-array compute energy, pJ.
    pub compute_energy_pj: f64,
}

impl MappingCost {
    pub fn total_ns(&self) -> f64 {
        self.x_load_ns + self.w_load_ns + self.compute_ns
    }

    pub fn energy_pj(&self) -> f64 {
        self.load_energy_pj + self.compute_energy_pj
    }
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Evaluate one mapping on one layer (Table VIII row).
///
/// `unroll_l` is the CS unrolling factor across KN (L in Table VII);
/// ignored by the other mappings.
pub fn evaluate_mapping(
    kind: MappingKind,
    layer: &ConvLayer,
    hw: &HwParams,
    scheme: &dyn AdditionScheme,
    unroll_l: usize,
) -> MappingCost {
    let t = ArrayTiming::default();
    let (n, kn) = (layer.n, layer.kn);
    let i = layer.i_dim();
    let j = layer.j_dim();
    let hxw = layer.h * layer.w;
    let (mh, mw) = (hw.mh, hw.mw);
    let s = layer.stride;

    // A pipelined accumulation step (one operand folded into a partial
    // sum): consecutive bit-serial adds overlap — bit 0 of add k+1 only
    // needs bit 0 of add k — so a steady-state step costs ~3 bit cycles.
    let bit_cycle = scheme.vector_add_latency_ns(1, mw as u32);
    let step_ns = 3.0 * bit_cycle;
    // One SACU weight-register refill (a 2-bit filter chunk) per bus turn.
    let t_wload = 9.86;
    let cmas = hw.cmas as u64;

    let (x_load_times, rows_per_load, w_load_times, parallel_cols, occupied, steps, util, endur);
    // weight loads pay a serialization factor when one bus cluster (64
    // CMAs) must deliver distinct chunks to many arrays
    let mut w_serial = 1u64;
    match kind {
        MappingKind::DirectOs => {
            // sliding-window direct conv: inherently sequential (§III-C1),
            // no replication benefit from spare CMAs
            x_load_times = (ceil_div(layer.c, mh) * ceil_div(hxw, mw)) as u64;
            rows_per_load = mh;
            w_load_times =
                (ceil_div(layer.c, mh) * layer.kh * ceil_div(hxw, mw) * layer.kw) as u64;
            parallel_cols = (mw / s).min(hxw / s);
            occupied = (kn * n) as u64;
            w_serial = (occupied.min(cmas) / 64).max(1);
            steps = (ceil_div(layer.c, mh) * ceil_div(i, mw) * layer.kh * layer.kw * mh) as u64;
            util = parallel_cols as f64 / mw as f64 * 0.765 / 0.5; // stride holes
            endur = mh as u32; // fixed accumulator rows take every write
        }
        MappingKind::Img2ColOs => {
            x_load_times = (ceil_div(j, mh) * ceil_div(i, mw)) as u64;
            rows_per_load = mh;
            w_load_times = x_load_times;
            parallel_cols = mw.min(i);
            occupied = (kn * n) as u64;
            w_serial = (occupied.min(cmas) / 64).max(1);
            // output-stationary instances replicate over spare CMAs
            let repl = (cmas / occupied.max(1)).max(1);
            steps =
                (ceil_div(j, mh) * ceil_div(i, mw) * mh) as u64 / repl.min(x_load_times.max(1));
            util = parallel_cols as f64 / mw as f64;
            endur = mh as u32;
        }
        MappingKind::Img2ColIs => {
            x_load_times = 1;
            rows_per_load = mh;
            w_load_times = kn as u64;
            parallel_cols = mw.min(n * i);
            occupied = (ceil_div(j, mh) * ceil_div(n * i, mw)) as u64;
            // replicate the stationary activations to process filters in
            // parallel waves across the spare CMAs
            let repl = (cmas / occupied.max(1)).clamp(1, kn as u64);
            steps = (kn as u64).div_ceil(repl) * mh as u64;
            util = (n * i) as f64 / (ceil_div(n * i, mw) * mw) as f64 * (j as f64)
                / (ceil_div(j, mh) * mh) as f64;
            endur = mh as u32;
        }
        MappingKind::Img2ColWs => {
            // weights pinned; activation tiles stream through (like OS)
            x_load_times = (ceil_div(j, mh) * ceil_div(i, mw)) as u64;
            rows_per_load = mh;
            w_load_times = 1;
            parallel_cols = mw.min(i);
            occupied = (ceil_div(j, mh) * kn) as u64;
            w_serial = (occupied.min(cmas) / 64).max(1);
            steps = (n * ceil_div(i, mw) * mh) as u64;
            util = parallel_cols as f64 / mw as f64;
            endur = mh as u32;
        }
        MappingKind::Img2ColCs => {
            let mh_eff = mh / 2; // interval rows halve the effective height
            x_load_times = 1;
            rows_per_load = mh_eff; // half the rows to write per CMA
            // filters pair up per refill (halved MH -> half the chunks)
            w_load_times = (kn as u64 / 2).max(1);
            parallel_cols = mw.min(n * i);
            occupied =
                (ceil_div(j, mh_eff) * ceil_div(n * i, mw) * unroll_l.max(1)) as u64;
            // per-CMA chains are half as long as IS (mh_eff operands), and
            // the chip replicates instances like IS
            let occ_one = (ceil_div(j, mh_eff) * ceil_div(n * i, mw)) as u64;
            let repl = (cmas / occ_one.max(1)).clamp(1, kn as u64);
            steps = (kn as u64).div_ceil(repl) * mh_eff as u64;
            // half the array holds activations, half holds intervals
            util = 0.5
                * ((n * i) as f64 / (ceil_div(n * i, mw) * mw) as f64)
                * (j as f64 / (ceil_div(j, mh_eff) * mh_eff) as f64);
            endur = 1; // rotation spreads partial-sum writes
        }
    }

    // Scale to the chip: if a full instance needs more CMAs than exist,
    // the work proceeds in waves (Fig. 9 (b)/(c)).
    let waves = occupied.div_ceil(hw.cmas as u64).max(1);
    let x_writes = x_load_times * (rows_per_load * mw) as u64 * occupied.min(hw.cmas as u64);
    let w_writes = w_load_times * mh as u64;

    // Loading time: row-stripe writes, one per bit-plane row, CMAs and
    // columns in parallel (the x_load_times formulas already count
    // per-tile reloads, so waves scale only the compute phase).
    let x_load_ns =
        x_load_times as f64 * rows_per_load as f64 * hw.op_bits as f64 * t.t_write_ns;
    let w_load_ns = w_load_times as f64 * t_wload * w_serial as f64;
    let compute_ns = steps as f64 * step_ns * waves as f64;

    // Energy: writes dominate loading; compute energy follows the scheme's
    // per-add energy (acc-width adds across the occupied columns).
    let e = crate::circuit::calibration::ArrayEnergy::default();
    let load_energy_pj = x_writes as f64 / mw as f64 * e.e_write_row_pj;
    let compute_energy_pj =
        steps as f64 * scheme.vector_add_energy_pj(3, parallel_cols as u32) * waves as f64;

    MappingCost {
        kind,
        x_load_times,
        x_writes,
        w_load_times,
        w_writes,
        parallel_cols,
        occupied_cmas: occupied,
        waves,
        utilization: util.min(1.0),
        x_load_ns,
        w_load_ns,
        compute_ns,
        max_cell_write_factor: endur,
        load_energy_pj,
        compute_energy_pj,
    }
}

/// Evaluate all five mappings (the Table VIII sweep) with the paper's
/// CS unroll factor choice (largest L that still fits the chip).
pub fn evaluate_all(
    layer: &ConvLayer,
    hw: &HwParams,
    scheme: &dyn AdditionScheme,
) -> Vec<MappingCost> {
    let base_cs = (ceil_div(2 * layer.j_dim(), hw.mh)
        * ceil_div(layer.n * layer.i_dim(), hw.mw))
    .max(1);
    let l = (hw.cmas / base_cs).clamp(1, layer.kn);
    MappingKind::ALL
        .iter()
        .map(|&k| evaluate_mapping(k, layer, hw, scheme, l))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::addition::{scheme as addition_scheme};
    use crate::circuit::sense_amp::SaKind;
    use crate::nn::resnet::resnet18_layer10;

    fn eval_layer10() -> Vec<MappingCost> {
        let layer = resnet18_layer10();
        let hw = HwParams::default();
        let fat = addition_scheme(SaKind::Fat);
        evaluate_all(&layer, &hw, fat.as_ref())
    }

    #[test]
    fn cs_is_fastest_mapping_on_layer10() {
        // Table VIII: Img2Col-CS achieves the highest speedup (6.86x over
        // Direct-OS; IS 4.88x).
        let costs = eval_layer10();
        let by_kind = |k: MappingKind| costs.iter().find(|c| c.kind == k).unwrap().total_ns();
        let direct = by_kind(MappingKind::DirectOs);
        let cs = by_kind(MappingKind::Img2ColCs);
        let is = by_kind(MappingKind::Img2ColIs);
        assert!(cs < is, "CS {cs} must beat IS {is}");
        let speedup_cs = direct / cs;
        let speedup_is = direct / is;
        assert!(speedup_cs > speedup_is);
        // shape: CS speedup in the right ballpark of the paper's 6.86x
        assert!(
            (3.0..14.0).contains(&speedup_cs),
            "CS speedup {speedup_cs} out of range"
        );
    }

    #[test]
    fn is_and_cs_load_activations_once() {
        let costs = eval_layer10();
        for c in &costs {
            match c.kind {
                MappingKind::Img2ColIs | MappingKind::Img2ColCs => {
                    assert_eq!(c.x_load_times, 1, "{:?}", c.kind)
                }
                _ => assert!(c.x_load_times > 1, "{:?}", c.kind),
            }
        }
    }

    #[test]
    fn cs_halves_loading_vs_is() {
        // Table VIII: CS x-loading 1354 ns vs IS 2708 ns (interval rows).
        let costs = eval_layer10();
        let is = costs.iter().find(|c| c.kind == MappingKind::Img2ColIs).unwrap();
        let cs = costs.iter().find(|c| c.kind == MappingKind::Img2ColCs).unwrap();
        assert!((is.x_load_ns / cs.x_load_ns - 2.0).abs() < 0.01);
    }

    #[test]
    fn x_loading_times_match_table8_within_10pct() {
        // Table VIII X/Ax loading: Direct-OS 21668, Img2Col-OS 48753,
        // IS 2708, CS 1354 ns.
        let costs = eval_layer10();
        let expect = [
            (MappingKind::DirectOs, 21668.0),
            (MappingKind::Img2ColOs, 48753.0),
            (MappingKind::Img2ColIs, 2708.0),
            (MappingKind::Img2ColWs, 48753.0),
            (MappingKind::Img2ColCs, 1354.0),
        ];
        for (k, want) in expect {
            let got = costs.iter().find(|c| c.kind == k).unwrap().x_load_ns;
            let err = (got - want).abs() / want;
            assert!(err < 0.10, "{k:?}: {got} vs paper {want} ({:.0}% off)", err * 100.0);
        }
    }

    #[test]
    fn ws_loads_weights_once() {
        let costs = eval_layer10();
        let ws = costs.iter().find(|c| c.kind == MappingKind::Img2ColWs).unwrap();
        assert_eq!(ws.w_load_times, 1);
    }

    #[test]
    fn endurance_factor_cs_vs_rest() {
        // Table VIII last column: 64x for everything except CS's 1x.
        let costs = eval_layer10();
        for c in &costs {
            match c.kind {
                MappingKind::Img2ColCs => assert_eq!(c.max_cell_write_factor, 1),
                _ => assert_eq!(c.max_cell_write_factor, 64, "{:?}", c.kind),
            }
        }
    }

    #[test]
    fn is_has_full_parallel_columns() {
        // Table VIII: IS and CS reach 256/256 parallel columns.
        let costs = eval_layer10();
        for c in &costs {
            match c.kind {
                MappingKind::Img2ColIs | MappingKind::Img2ColCs => {
                    assert_eq!(c.parallel_cols, 256, "{:?}", c.kind)
                }
                MappingKind::DirectOs => assert_eq!(c.parallel_cols, 128), // MW/S
                _ => assert_eq!(c.parallel_cols, 196), // min(MW, I)
            }
        }
    }

    #[test]
    fn cs_energy_way_below_direct_os() {
        // Table VIII: CS & IS use ~0.57x the energy of Direct-OS
        let costs = eval_layer10();
        let direct = costs.iter().find(|c| c.kind == MappingKind::DirectOs).unwrap();
        let cs = costs.iter().find(|c| c.kind == MappingKind::Img2ColCs).unwrap();
        assert!(
            cs.energy_pj() < 0.8 * direct.energy_pj(),
            "CS {} vs Direct {}",
            cs.energy_pj(),
            direct.energy_pj()
        );
    }

    #[test]
    fn utilization_ordering() {
        // IS has the highest utilization (94% in the paper); CS pays half
        // for the interval rows (47%).
        let costs = eval_layer10();
        let is = costs.iter().find(|c| c.kind == MappingKind::Img2ColIs).unwrap();
        let cs = costs.iter().find(|c| c.kind == MappingKind::Img2ColCs).unwrap();
        assert!(is.utilization > 0.85);
        assert!((cs.utilization - is.utilization / 2.0).abs() < 0.05);
    }

    #[test]
    fn link_ecc_charges_one_check_byte_per_flit() {
        let mut hw = HwParams::default();
        assert_eq!(hw.wire_bytes(64), 64, "no ECC, no overhead");
        hw.link_ecc = true;
        assert_eq!(hw.wire_bytes(64), 72, "8 flits -> 8 check bytes");
        assert_eq!(hw.wire_bytes(0), 0);
        assert_eq!(hw.wire_bytes(9), 9 + 2, "partial flits still pay a check byte");
        // 12.5% on flit-aligned payloads
        assert_eq!(hw.wire_bytes(4096), 4096 + 512);
    }

    #[test]
    fn small_layer_fits_single_wave() {
        let layer = crate::nn::resnet::twn_cnn_layers(4)[0];
        let hw = HwParams::default();
        let fat = addition_scheme(SaKind::Fat);
        let costs = evaluate_all(&layer, &hw, fat.as_ref());
        for c in costs {
            assert!(c.waves >= 1);
        }
    }
}
