//! A minimal JSON value parser (the crate is deliberately dependency-free).
//!
//! The repo emits JSON in two places — the bench records
//! ([`crate::bench_harness::BenchRun::to_json`]) and the Chrome
//! trace-event files ([`crate::coordinator::telemetry`]) — and until now
//! could only *write* it.  Validation (the `--trace-out` self-check, the
//! baseline round-trip test) needs to read it back, so this module is a
//! small, strict, recursive-descent parser over the full JSON grammar:
//! objects, arrays, strings with escapes (`\uXXXX` included), numbers,
//! booleans, null.  It is not streaming and not fast — it exists for
//! validators and tests, never on a serving path.

use crate::error::{bail, Result};

/// A parsed JSON value.  Object keys keep their file order (the writers
/// in this crate are deterministic, and validators check byte shapes).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member by key (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {} of the JSON document", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected `{}` at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("malformed literal at byte {} (expected `{word}`)", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii slice");
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => bail!("malformed number `{text}` at byte {start}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok());
                            match hex.and_then(char::from_u32) {
                                Some(c) => {
                                    out.push(c);
                                    self.pos += 4;
                                }
                                // surrogate pairs are out of scope: no
                                // writer in this crate emits them
                                None => bail!("bad \\u escape at byte {}", self.pos),
                            }
                        }
                        other => bail!(
                            "bad escape {:?} at byte {}",
                            other.map(|c| c as char),
                            self.pos
                        ),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 passes through untouched
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| crate::error::anyhow!("invalid UTF-8 in string: {e}"))?;
                    let c = rest.chars().next().expect("non-empty checked");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!(
                    "expected `,` or `]` at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            members.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                other => bail!(
                    "expected `,` or `}}` at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a b\"").unwrap(), Json::Str("a b".into()));
        assert_eq!(
            parse("[1, 2, [3]]").unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Num(2.0), Json::Arr(vec![Json::Num(3.0)])])
        );
        let obj = parse("{\"a\": 1, \"b\": {\"c\": []}}").unwrap();
        assert_eq!(obj.get("a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(obj.get("b").and_then(|b| b.get("c")).and_then(Json::as_arr), Some(&[][..]));
    }

    #[test]
    fn unescapes_strings() {
        assert_eq!(
            parse(r#""q\" b\\ n\n u\u0041""#).unwrap(),
            Json::Str("q\" b\\ n\n uA".into())
        );
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "[1] x", "nul", "\"open", "{\"a\":}", "1.2.3",
            "\"\\u12\"", "Infinity",
        ] {
            assert!(parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn round_trips_the_bench_record_shape() {
        let doc = parse(
            "{\n  \"name\": \"x\",\n  \"measurements\": [\n    {\"label\": \"a\", \
\"median_ns\": 12.5, \"mad_ns\": 0.5, \"samples\": 7}\n  ],\n  \"checks\": [],\n  \
\"failed_checks\": 0\n}\n",
        )
        .unwrap();
        let ms = doc.get("measurements").and_then(Json::as_arr).unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].get("label").and_then(Json::as_str), Some("a"));
        assert_eq!(ms[0].get("median_ns").and_then(Json::as_f64), Some(12.5));
    }
}
