//! Reference layer implementations — eqs. (4)-(6) of the paper.
//!
//! `conv2d_ternary` is the direct-convolution oracle (an actual multiply by
//! the ternary weight); the accelerator path computes the same values with
//! additions only, and the two are compared in integration tests.

use super::tensor::Tensor4;

/// Ternary weight tensor in (KN, C, KH, KW) layout.
#[derive(Debug, Clone)]
pub struct TernaryFilter {
    pub kn: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub w: Vec<i8>,
}

impl TernaryFilter {
    pub fn new(kn: usize, c: usize, kh: usize, kw: usize, w: Vec<i8>) -> Self {
        assert_eq!(w.len(), kn * c * kh * kw);
        Self { kn, c, kh, kw, w }
    }

    #[inline]
    pub fn get(&self, kn: usize, c: usize, i: usize, j: usize) -> i8 {
        self.w[((kn * self.c + c) * self.kh + i) * self.kw + j]
    }

    /// Weights of filter `kn` flattened in (c, kh, kw) order — the J
    /// ordering of the Img2Col GEMM.
    pub fn filter_flat(&self, kn: usize) -> Vec<i8> {
        let len = self.c * self.kh * self.kw;
        self.w[kn * len..(kn + 1) * len].to_vec()
    }

    pub fn sparsity(&self) -> f64 {
        crate::ternary::sparsity(&self.w)
    }
}

/// Direct ternary convolution (eq. 4), stride `s`, zero padding `p`.
pub fn conv2d_ternary(x: &Tensor4, f: &TernaryFilter, s: usize, p: usize) -> Tensor4 {
    assert_eq!(x.c, f.c, "channel mismatch");
    let oh = (x.h + 2 * p - f.kh) / s + 1;
    let ow = (x.w + 2 * p - f.kw) / s + 1;
    let mut y = Tensor4::zeros(x.n, f.kn, oh, ow);
    for n in 0..x.n {
        for kn in 0..f.kn {
            for out_h in 0..oh {
                for out_w in 0..ow {
                    let mut acc = 0.0f32;
                    for c in 0..x.c {
                        for i in 0..f.kh {
                            for j in 0..f.kw {
                                let wv = f.get(kn, c, i, j);
                                if wv == 0 {
                                    continue;
                                }
                                let xv = x.get_padded(
                                    n,
                                    c,
                                    (out_h * s + i) as isize - p as isize,
                                    (out_w * s + j) as isize - p as isize,
                                );
                                acc += wv as f32 * xv;
                            }
                        }
                    }
                    y.set(n, kn, out_h, out_w, acc);
                }
            }
        }
    }
    y
}

/// ReLU (eq. 5), in place.
pub fn relu(x: &mut Tensor4) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Folded batch normalization (eq. 6 folded to scale/shift), per channel,
/// in place — what the paper's DPU applies.
pub fn batch_norm(x: &mut Tensor4, gamma: &[f32], beta: &[f32]) {
    assert_eq!(gamma.len(), x.c);
    assert_eq!(beta.len(), x.c);
    for n in 0..x.n {
        for c in 0..x.c {
            for h in 0..x.h {
                for w in 0..x.w {
                    let i = x.idx(n, c, h, w);
                    x.data[i] = x.data[i] * gamma[c] + beta[c];
                }
            }
        }
    }
}

/// Global average pooling: (N, C, H, W) -> per-(n, c) means.
pub fn global_avg_pool(x: &Tensor4) -> Vec<Vec<f32>> {
    let denom = (x.h * x.w) as f32;
    (0..x.n)
        .map(|n| {
            (0..x.c)
                .map(|c| {
                    let mut s = 0.0;
                    for h in 0..x.h {
                        for w in 0..x.w {
                            s += x.get(n, c, h, w);
                        }
                    }
                    s / denom
                })
                .collect()
        })
        .collect()
}

/// Ternary fully connected layer: y[n][o] = sum_i x[n][i] * w[i][o] + b[o].
pub fn linear_ternary(x: &[Vec<f32>], w: &[i8], in_dim: usize, out_dim: usize, b: &[f32]) -> Vec<Vec<f32>> {
    assert_eq!(w.len(), in_dim * out_dim);
    assert_eq!(b.len(), out_dim);
    x.iter()
        .map(|row| {
            assert_eq!(row.len(), in_dim);
            (0..out_dim)
                .map(|o| {
                    let mut acc = b[o];
                    for (i, &xv) in row.iter().enumerate() {
                        let wv = w[i * out_dim + o];
                        if wv != 0 {
                            acc += wv as f32 * xv;
                        }
                    }
                    acc
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop_check, Rng};

    #[test]
    fn identity_kernel_convolution() {
        // 1x1 kernel of +1 reproduces the input
        let mut x = Tensor4::zeros(1, 1, 3, 3);
        let mut rng = Rng::new(1);
        x.fill_random_ints(&mut rng, 0, 10);
        let f = TernaryFilter::new(1, 1, 1, 1, vec![1]);
        let y = conv2d_ternary(&x, &f, 1, 0);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn negation_kernel() {
        let mut x = Tensor4::zeros(1, 1, 2, 2);
        x.data = vec![1.0, 2.0, 3.0, 4.0];
        let f = TernaryFilter::new(1, 1, 1, 1, vec![-1]);
        let y = conv2d_ternary(&x, &f, 1, 0);
        assert_eq!(y.data, vec![-1.0, -2.0, -3.0, -4.0]);
    }

    #[test]
    fn box_sum_kernel_with_padding() {
        // 3x3 all-ones kernel at the corner of a ones image with pad 1:
        // only 4 in-bounds taps
        let x = Tensor4::from_vec(1, 1, 3, 3, vec![1.0; 9]);
        let f = TernaryFilter::new(1, 1, 3, 3, vec![1; 9]);
        let y = conv2d_ternary(&x, &f, 1, 1);
        assert_eq!(y.shape(), (1, 1, 3, 3));
        assert_eq!(y.get(0, 0, 0, 0), 4.0);
        assert_eq!(y.get(0, 0, 1, 1), 9.0);
    }

    #[test]
    fn stride_two_halves_output() {
        // ResNet-18 layer 10 geometry: 28x28, k3, s2, p1 -> 14x14
        let x = Tensor4::zeros(1, 2, 28, 28);
        let f = TernaryFilter::new(4, 2, 3, 3, vec![1; 4 * 2 * 9]);
        let y = conv2d_ternary(&x, &f, 2, 1);
        assert_eq!(y.shape(), (1, 4, 14, 14));
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut x = Tensor4::from_vec(1, 1, 1, 4, vec![-1.0, 0.0, 2.0, -0.5]);
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn batch_norm_scale_shift() {
        let mut x = Tensor4::from_vec(1, 2, 1, 2, vec![1.0, 2.0, 3.0, 4.0]);
        batch_norm(&mut x, &[2.0, 0.5], &[1.0, -1.0]);
        assert_eq!(x.data, vec![3.0, 5.0, 0.5, 1.0]);
    }

    #[test]
    fn global_pool_means() {
        let x = Tensor4::from_vec(1, 2, 1, 2, vec![1.0, 3.0, 10.0, 20.0]);
        let p = global_avg_pool(&x);
        assert_eq!(p, vec![vec![2.0, 15.0]]);
    }

    #[test]
    fn linear_matches_manual() {
        // 2 -> 2, w = [[1,-1],[0,1]] (row i = input, col o = output)
        let y = linear_ternary(
            &[vec![3.0, 4.0]],
            &[1, -1, 0, 1],
            2,
            2,
            &[0.5, 0.0],
        );
        assert_eq!(y, vec![vec![3.5, 1.0]]);
    }

    #[test]
    fn property_zero_weights_give_zero_output() {
        prop_check(
            "all-zero filter -> zero output",
            20,
            5,
            |rng| {
                let mut x = Tensor4::zeros(1, 2, 5, 5);
                x.fill_random_ints(rng, -10, 10);
                x
            },
            |x| {
                let f = TernaryFilter::new(3, 2, 3, 3, vec![0; 3 * 2 * 9]);
                let y = conv2d_ternary(x, &f, 1, 1);
                if y.data.iter().all(|&v| v == 0.0) {
                    Ok(())
                } else {
                    Err("non-zero output".into())
                }
            },
        );
    }

    #[test]
    fn property_conv_is_linear_in_input() {
        // conv(x1 + x2) == conv(x1) + conv(x2) for integer-valued inputs
        prop_check(
            "conv linearity",
            10,
            9,
            |rng| {
                let mut x1 = Tensor4::zeros(1, 2, 6, 6);
                let mut x2 = Tensor4::zeros(1, 2, 6, 6);
                x1.fill_random_ints(rng, -8, 8);
                x2.fill_random_ints(rng, -8, 8);
                let w = rng.ternary_vec(2 * 2 * 9, 0.5);
                (x1, x2, w)
            },
            |(x1, x2, w)| {
                let f = TernaryFilter::new(2, 2, 3, 3, w.clone());
                let mut xs = x1.clone();
                for (a, b) in xs.data.iter_mut().zip(&x2.data) {
                    *a += b;
                }
                let lhs = conv2d_ternary(&xs, &f, 1, 1);
                let y1 = conv2d_ternary(x1, &f, 1, 1);
                let y2 = conv2d_ternary(x2, &f, 1, 1);
                for i in 0..lhs.data.len() {
                    if (lhs.data[i] - (y1.data[i] + y2.data[i])).abs() > 1e-4 {
                        return Err(format!("nonlinear at {i}"));
                    }
                }
                Ok(())
            },
        );
    }
}
