//! Minimal NN substrate: tensors, reference layers, network geometry.
//!
//! These are the *functional references* the accelerator simulation is
//! validated against (and the workload definitions the mapping/bench code
//! sweeps).  The heavy lifting at inference time happens in the CMAs; this
//! module is deliberately straightforward CPU code.

pub mod layers;
pub mod ops;
pub mod resnet;
pub mod tensor;
pub mod workloads;

pub use ops::{GemmLayer, GroupedConvLayer, LayerOp, OpUnit};
pub use resnet::{resnet18_conv_layers, ConvLayer};
pub use tensor::Tensor4;
