//! The ternary op IR: the layer shapes the accelerator can execute.
//!
//! Historically the serving stack hardcoded one shape — a dense 2-D
//! convolution ([`ConvLayer`]).  The SACU + fast-addition scheme is
//! op-agnostic: anything that lowers to a ternary dot product maps onto
//! the CMAs through Img2Col.  [`LayerOp`] names the three shapes the
//! stack serves and gives every consumer one vocabulary:
//!
//! - [`LayerOp::Conv`] — the classic dense convolution, unchanged.
//! - [`LayerOp::GroupedConv`] — grouped/depthwise convolution: `groups`
//!   independent convs over disjoint input-channel slices (depthwise is
//!   the `cg = kg = 1` special case).  Stresses the mapper very
//!   differently from 3x3 convs: tiny per-group KN, high layer count.
//! - [`LayerOp::Gemm`] — a ternary GEMM `y[b] = x[b] @ w` lowered to a
//!   1x1 conv with degenerate geometry (`kh = kw = 1`, `h = m`,
//!   `w = 1`): Img2Col of that geometry is the identity, so the GEMM
//!   streams through the existing conv machinery untouched.
//!
//! Every op decomposes into [`OpUnit`]s — plain `ConvLayer`s the chip
//! executes natively, plus the channel offsets placing each unit's input
//! and output inside the layer's tensors.  Conv and Gemm are one unit; a
//! grouped conv is one unit per group.  Everything downstream (grid
//! planning, register packing, footprints, KN splitting) operates on
//! units, which is how the op refactor keeps the conv paths
//! byte-identical to the pre-IR stack.

use crate::nn::resnet::ConvLayer;

/// A ternary GEMM: `b` independent `(m x k) @ (k x n)` products sharing
/// one resident ternary weight matrix.  Weights are n-major rows of
/// length k — exactly `TernaryFilter` with `c = k, kh = kw = 1` — so the
/// committed python kernel (`python/compile/kernels/ternary_gemm.py`,
/// `y = x @ w`) and the chip path share one layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmLayer {
    pub name: &'static str,
    /// Independent GEMMs per request (the batch dimension).
    pub b: usize,
    /// Rows of the activation matrix (e.g. transformer sequence length).
    pub m: usize,
    /// Reduction dimension.
    pub k: usize,
    /// Output features — the KN dimension on the chip.
    pub n: usize,
}

impl GemmLayer {
    /// The degenerate conv geometry this GEMM lowers to.  A 1x1 kernel at
    /// stride 1 makes Img2Col the identity layout: column `(b, m)` holds
    /// activation row `m` of batch `b`, J runs over `k`.
    pub fn lower(&self) -> ConvLayer {
        ConvLayer {
            name: self.name,
            n: self.b,
            c: self.k,
            h: self.m,
            w: 1,
            kn: self.n,
            kh: 1,
            kw: 1,
            stride: 1,
            pad: 0,
        }
    }
}

/// A grouped convolution: `groups` independent convs, group `g` reading
/// input channels `[c_offset + g*cg, c_offset + (g+1)*cg)` and producing
/// output channels `[g*kg, (g+1)*kg)`.  Depthwise is `cg = kg = 1` with
/// `groups` equal to the channel count.
///
/// `c_offset`/`c_in` record where the groups sit inside the *incoming*
/// tensor: an unsliced layer has `c_offset = 0, c_in = groups * cg`; a
/// KN slice (always cut at group boundaries) keeps the full `c_in` and
/// bumps `c_offset`, so every slice still consumes the same gathered
/// activation tensor — the contract filter-dimension tensor parallelism
/// relies on for plain convs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupedConvLayer {
    pub name: &'static str,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    /// Independent groups.
    pub groups: usize,
    /// Input channels per group.
    pub cg: usize,
    /// Output filters per group — the KN split granularity.
    pub kg: usize,
    /// Input channel where group 0 starts (non-zero only on KN slices).
    pub c_offset: usize,
    /// Channels the incoming tensor carries (>= c_offset + groups * cg).
    pub c_in: usize,
}

impl GroupedConvLayer {
    /// A depthwise layer over `c` channels: one 1-in/1-out group per
    /// channel.
    pub fn depthwise(name: &'static str, base: ConvLayer) -> Self {
        Self {
            name,
            n: base.n,
            h: base.h,
            w: base.w,
            kh: base.kh,
            kw: base.kw,
            stride: base.stride,
            pad: base.pad,
            groups: base.c,
            cg: 1,
            kg: 1,
            c_offset: 0,
            c_in: base.c,
        }
    }

    /// Total output channels across groups.
    pub fn kn(&self) -> usize {
        self.groups * self.kg
    }

    /// The plain conv one group executes (channel placement aside).
    pub fn unit(&self) -> ConvLayer {
        ConvLayer {
            name: self.name,
            n: self.n,
            c: self.cg,
            h: self.h,
            w: self.w,
            kn: self.kg,
            kh: self.kh,
            kw: self.kw,
            stride: self.stride,
            pad: self.pad,
        }
    }
}

/// One native execution unit of an op: a plain conv plus the channel
/// offsets placing it inside the layer.  `c0` is the first input channel
/// the unit reads from the incoming tensor; `k0` the first output
/// channel (== filter row) it produces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpUnit {
    pub conv: ConvLayer,
    pub c0: usize,
    pub k0: usize,
}

/// A ternary layer op — the IR every serving layer dispatches on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerOp {
    Conv(ConvLayer),
    GroupedConv(GroupedConvLayer),
    Gemm(GemmLayer),
}

impl LayerOp {
    pub fn name(&self) -> &'static str {
        match self {
            LayerOp::Conv(l) => l.name,
            LayerOp::GroupedConv(g) => g.name,
            LayerOp::Gemm(g) => g.name,
        }
    }

    /// The batch dimension (independent requests folded per tensor).
    pub fn batch(&self) -> usize {
        match self {
            LayerOp::Conv(l) => l.n,
            LayerOp::GroupedConv(g) => g.n,
            LayerOp::Gemm(g) => g.b,
        }
    }

    /// Raw output channels (before any epilogue reshaping).
    pub fn kn(&self) -> usize {
        match self {
            LayerOp::Conv(l) => l.kn,
            LayerOp::GroupedConv(g) => g.kn(),
            LayerOp::Gemm(g) => g.n,
        }
    }

    /// The tensor geometry this op consumes: (n, c, h, w).
    pub fn in_geometry(&self) -> (usize, usize, usize, usize) {
        match self {
            LayerOp::Conv(l) => (l.n, l.c, l.h, l.w),
            LayerOp::GroupedConv(g) => (g.n, g.c_in, g.h, g.w),
            LayerOp::Gemm(g) => (g.b, g.k, g.m, 1),
        }
    }

    /// The conv output geometry: (n, kn, oh, ow) — before pool/epilogue.
    pub fn out_geometry(&self) -> (usize, usize, usize, usize) {
        match self {
            LayerOp::Conv(l) => (l.n, l.kn, l.oh(), l.ow()),
            LayerOp::GroupedConv(g) => {
                let u = g.unit();
                (g.n, g.kn(), u.oh(), u.ow())
            }
            LayerOp::Gemm(g) => (g.b, g.n, g.m, 1),
        }
    }

    /// Resident ternary weight count.
    pub fn weights(&self) -> usize {
        let (kn, c, kh, kw) = self.filter_dims();
        kn * c * kh * kw
    }

    /// Multiply-accumulates of the dense op.
    pub fn macs(&self) -> u64 {
        match self {
            LayerOp::Conv(l) => l.macs(),
            LayerOp::GroupedConv(g) => g.groups as u64 * g.unit().macs(),
            LayerOp::Gemm(g) => g.lower().macs(),
        }
    }

    /// The `TernaryFilter` dims holding this op's weights:
    /// (kn, c, kh, kw) with rows in output-channel order.  A grouped
    /// conv's rows are unit-local (length `cg * kh * kw`), so row `k`
    /// belongs to group `k / kg`.
    pub fn filter_dims(&self) -> (usize, usize, usize, usize) {
        match self {
            LayerOp::Conv(l) => (l.kn, l.c, l.kh, l.kw),
            LayerOp::GroupedConv(g) => (g.kn(), g.cg, g.kh, g.kw),
            LayerOp::Gemm(g) => (g.n, g.k, 1, 1),
        }
    }

    /// The KN-split granularity: slices must be multiples of this (a
    /// grouped conv cannot be cut inside a group — the group's filters
    /// share input channels no other chip would hold).
    pub fn kn_granularity(&self) -> usize {
        match self {
            LayerOp::GroupedConv(g) => g.kg,
            _ => 1,
        }
    }

    /// This op serving `k` fused requests per tensor.
    pub fn with_batch_factor(&self, k: usize) -> LayerOp {
        match *self {
            LayerOp::Conv(mut l) => {
                l.n *= k;
                LayerOp::Conv(l)
            }
            LayerOp::GroupedConv(mut g) => {
                g.n *= k;
                LayerOp::GroupedConv(g)
            }
            LayerOp::Gemm(mut g) => {
                g.b *= k;
                LayerOp::Gemm(g)
            }
        }
    }

    /// The native execution units: plain convs plus channel placement.
    pub fn units(&self) -> Vec<OpUnit> {
        match self {
            LayerOp::Conv(l) => vec![OpUnit { conv: *l, c0: 0, k0: 0 }],
            LayerOp::Gemm(g) => vec![OpUnit { conv: g.lower(), c0: 0, k0: 0 }],
            LayerOp::GroupedConv(g) => {
                let u = g.unit();
                (0..g.groups)
                    .map(|gi| OpUnit { conv: u, c0: g.c_offset + gi * g.cg, k0: gi * g.kg })
                    .collect()
            }
        }
    }

    /// The contiguous output-channel slice `[k0, k1)` of this op — the
    /// per-chip unit of KN tensor parallelism.  The caller (`LayerSpec::
    /// slice_kn`) has already checked granularity; this only reshapes
    /// geometry.  Grouped slices keep `c_in` (they consume the full
    /// gathered tensor) and advance `c_offset` to their first group.
    pub fn slice_kn(&self, k0: usize, k1: usize) -> LayerOp {
        debug_assert!(k0 < k1 && k1 <= self.kn(), "bad KN slice [{k0}, {k1})");
        debug_assert!(k0 % self.kn_granularity() == 0 && k1 % self.kn_granularity() == 0);
        match *self {
            LayerOp::Conv(mut l) => {
                l.kn = k1 - k0;
                LayerOp::Conv(l)
            }
            LayerOp::Gemm(mut g) => {
                g.n = k1 - k0;
                LayerOp::Gemm(g)
            }
            LayerOp::GroupedConv(mut g) => {
                g.c_offset += (k0 / g.kg) * g.cg;
                g.groups = (k1 - k0) / g.kg;
                LayerOp::GroupedConv(g)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dw(c: usize) -> GroupedConvLayer {
        GroupedConvLayer::depthwise(
            "dw",
            ConvLayer { name: "dw", n: 2, c, h: 8, w: 8, kn: c, kh: 3, kw: 3, stride: 1, pad: 1 },
        )
    }

    #[test]
    fn gemm_lowers_to_degenerate_conv() {
        let g = GemmLayer { name: "g", b: 3, m: 16, k: 8, n: 12 };
        let l = g.lower();
        assert_eq!((l.n, l.c, l.h, l.w), (3, 8, 16, 1));
        assert_eq!((l.kn, l.kh, l.kw, l.stride, l.pad), (12, 1, 1, 1, 0));
        assert_eq!((l.oh(), l.ow()), (16, 1), "1x1/s1/p0 preserves spatial");
        let op = LayerOp::Gemm(g);
        assert_eq!(op.in_geometry(), (3, 8, 16, 1));
        assert_eq!(op.out_geometry(), (3, 12, 16, 1));
        assert_eq!(op.weights(), 8 * 12);
        assert_eq!(op.macs(), 3 * 16 * 8 * 12);
        assert_eq!(op.units().len(), 1);
    }

    #[test]
    fn grouped_units_partition_channels() {
        let g = dw(6);
        let op = LayerOp::GroupedConv(g);
        assert_eq!(op.kn(), 6);
        assert_eq!(op.kn_granularity(), 1);
        assert_eq!(op.in_geometry(), (2, 6, 8, 8));
        assert_eq!(op.weights(), 6 * 9, "one 3x3 kernel per channel");
        let units = op.units();
        assert_eq!(units.len(), 6);
        for (i, u) in units.iter().enumerate() {
            assert_eq!((u.c0, u.k0), (i, i));
            assert_eq!((u.conv.c, u.conv.kn), (1, 1));
        }
        // dense macs / c: each output channel reduces over 1 channel
        let dense = ConvLayer {
            name: "d", n: 2, c: 6, h: 8, w: 8, kn: 6, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        assert_eq!(op.macs(), dense.macs() / 6);
    }

    #[test]
    fn grouped_slice_advances_channel_offset() {
        let mut g = dw(8);
        g.kg = 2;
        g.cg = 2;
        g.groups = 4; // 4 groups x (2 in -> 2 out), kn = 8 over c_in = 8
        let op = LayerOp::GroupedConv(g);
        let s = op.slice_kn(4, 8);
        match s {
            LayerOp::GroupedConv(sg) => {
                assert_eq!(sg.groups, 2);
                assert_eq!(sg.c_offset, 4);
                assert_eq!(sg.c_in, 8, "slices consume the full gathered tensor");
                let units = s.units();
                assert_eq!(units[0].c0, 4);
                assert_eq!(units[1].c0, 6);
                assert_eq!(units[0].k0, 0, "output channels are slice-local");
            }
            _ => panic!("slice changed op kind"),
        }
        assert_eq!(s.in_geometry(), op.in_geometry());
    }

    #[test]
    fn batch_factor_scales_every_op_kind() {
        let conv = LayerOp::Conv(ConvLayer {
            name: "c", n: 2, c: 3, h: 8, w: 8, kn: 4, kh: 3, kw: 3, stride: 1, pad: 1,
        });
        let gemm = LayerOp::Gemm(GemmLayer { name: "g", b: 1, m: 4, k: 3, n: 5 });
        let grp = LayerOp::GroupedConv(dw(4));
        for (op, n0) in [(conv, 2), (gemm, 1), (grp, 2)] {
            let b = op.with_batch_factor(3);
            assert_eq!(b.batch(), 3 * n0);
            assert_eq!(b.kn(), op.kn());
            assert_eq!(b.weights(), op.weights());
        }
    }
}
