//! Network geometry tables — the workloads the paper evaluates.
//!
//! ResNet-18's convolution layers (ImageNet geometry, He et al. [17]),
//! including **layer 10**, the showcase layer of Table VIII:
//! (N, C, H, W) = (5, 128, 28, 28), (KN, KH, KW) = (256, 3, 3), S = 2.

/// Geometry of one convolution layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: &'static str,
    /// Batch size (the paper's Table VIII uses N = 5).
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub kn: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvLayer {
    pub fn oh(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn ow(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Img2Col I dimension: output pixels per image.
    pub fn i_dim(&self) -> usize {
        self.oh() * self.ow()
    }

    /// Img2Col J dimension: reduction length per output point.
    pub fn j_dim(&self) -> usize {
        self.c * self.kh * self.kw
    }

    /// Multiply-accumulates of the dense layer (eq. 4).
    pub fn macs(&self) -> u64 {
        (self.n * self.kn * self.i_dim() * self.j_dim()) as u64
    }

    /// Weight count.
    pub fn weights(&self) -> usize {
        self.kn * self.j_dim()
    }
}

/// The 17 convolution layers of ResNet-18 (3x3 backbone, ImageNet sizes),
/// batch 5 to match Table VIII.  Downsample (1x1) projections omitted —
/// the paper's Table VIII sweeps the 3x3 backbone.
pub fn resnet18_conv_layers() -> Vec<ConvLayer> {
    let mut layers = Vec::new();
    let n = 5;
    layers.push(ConvLayer { name: "conv1", n, c: 3, h: 224, w: 224, kn: 64, kh: 7, kw: 7, stride: 2, pad: 3 });
    // stage conv2_x: 56x56, 64ch
    for (i, name) in ["conv2_1a", "conv2_1b", "conv2_2a", "conv2_2b"].iter().enumerate() {
        let _ = i;
        layers.push(ConvLayer { name, n, c: 64, h: 56, w: 56, kn: 64, kh: 3, kw: 3, stride: 1, pad: 1 });
    }
    // stage conv3_x: first halves 56 -> 28, 64 -> 128ch
    layers.push(ConvLayer { name: "conv3_1a", n, c: 64, h: 56, w: 56, kn: 128, kh: 3, kw: 3, stride: 2, pad: 1 });
    layers.push(ConvLayer { name: "conv3_1b", n, c: 128, h: 28, w: 28, kn: 128, kh: 3, kw: 3, stride: 1, pad: 1 });
    layers.push(ConvLayer { name: "conv3_2a", n, c: 128, h: 28, w: 28, kn: 128, kh: 3, kw: 3, stride: 1, pad: 1 });
    layers.push(ConvLayer { name: "conv3_2b", n, c: 128, h: 28, w: 28, kn: 128, kh: 3, kw: 3, stride: 1, pad: 1 });
    // stage conv4_x: first halves 28 -> 14, 128 -> 256ch.
    // layers[9] is "layer 10" in the paper's 1-based counting: the Table
    // VIII showcase (C=128, H=W=28, KN=256, S=2).
    layers.push(ConvLayer { name: "conv4_1a(layer10)", n, c: 128, h: 28, w: 28, kn: 256, kh: 3, kw: 3, stride: 2, pad: 1 });
    layers.push(ConvLayer { name: "conv4_1b", n, c: 256, h: 14, w: 14, kn: 256, kh: 3, kw: 3, stride: 1, pad: 1 });
    layers.push(ConvLayer { name: "conv4_2a", n, c: 256, h: 14, w: 14, kn: 256, kh: 3, kw: 3, stride: 1, pad: 1 });
    layers.push(ConvLayer { name: "conv4_2b", n, c: 256, h: 14, w: 14, kn: 256, kh: 3, kw: 3, stride: 1, pad: 1 });
    // stage conv5_x: 14 -> 7, 256 -> 512ch
    layers.push(ConvLayer { name: "conv5_1a", n, c: 256, h: 14, w: 14, kn: 512, kh: 3, kw: 3, stride: 2, pad: 1 });
    layers.push(ConvLayer { name: "conv5_1b", n, c: 512, h: 7, w: 7, kn: 512, kh: 3, kw: 3, stride: 1, pad: 1 });
    layers.push(ConvLayer { name: "conv5_2a", n, c: 512, h: 7, w: 7, kn: 512, kh: 3, kw: 3, stride: 1, pad: 1 });
    layers.push(ConvLayer { name: "conv5_2b", n, c: 512, h: 7, w: 7, kn: 512, kh: 3, kw: 3, stride: 1, pad: 1 });
    layers
}

/// The Table VIII showcase layer.
pub fn resnet18_layer10() -> ConvLayer {
    resnet18_conv_layers()[9]
}

/// Chain-consistent scaled-down ResNet-18 backbone for *bit-accurate*
/// end-to-end simulation: channel counts divided by `ch_div` (minimum 4,
/// the 3-channel input stays 3), spatial sizes derived by propagating an
/// `input_hw` x `input_hw` image through the stem (7x7/s2 conv, then the
/// DPU's 2x2/s2 max pool) and the stride pattern.  Every layer's `kn`
/// equals the next layer's `c` by construction, so the table can be driven
/// layer-by-layer through the chip (see `coordinator::session`).
///
/// `ch_div = 1, input_hw = 224` reproduces the full ImageNet geometry of
/// [`resnet18_conv_layers`] (modulo the batch).
pub fn resnet18_conv_layers_scaled(batch: usize, input_hw: usize, ch_div: usize) -> Vec<ConvLayer> {
    assert!(batch > 0 && input_hw > 0 && ch_div > 0);
    let ch = |c: usize| (c / ch_div).max(4).min(c);
    fn seg(name: &'static str, n: usize, c: usize, h: usize, kn: usize, stride: usize) -> ConvLayer {
        ConvLayer { name, n, c, h, w: h, kn, kh: 3, kw: 3, stride, pad: 1 }
    }
    let mut layers = Vec::with_capacity(17);
    let conv1 = ConvLayer {
        name: "conv1", n: batch, c: 3, h: input_hw, w: input_hw,
        kn: ch(64), kh: 7, kw: 7, stride: 2, pad: 3,
    };
    // the DPU's 2x2/s2 max pool follows conv1 (floor semantics, min 1)
    let mut h = (conv1.oh() / 2).max(1);
    layers.push(conv1);
    let body: [(&'static str, usize, usize, usize); 16] = [
        ("conv2_1a", 64, 64, 1), ("conv2_1b", 64, 64, 1),
        ("conv2_2a", 64, 64, 1), ("conv2_2b", 64, 64, 1),
        ("conv3_1a", 64, 128, 2), ("conv3_1b", 128, 128, 1),
        ("conv3_2a", 128, 128, 1), ("conv3_2b", 128, 128, 1),
        ("conv4_1a", 128, 256, 2), ("conv4_1b", 256, 256, 1),
        ("conv4_2a", 256, 256, 1), ("conv4_2b", 256, 256, 1),
        ("conv5_1a", 256, 512, 2), ("conv5_1b", 512, 512, 1),
        ("conv5_2a", 512, 512, 1), ("conv5_2b", 512, 512, 1),
    ];
    for (name, c, kn, stride) in body {
        let l = seg(name, batch, ch(c), h, ch(kn), stride);
        h = l.oh();
        layers.push(l);
    }
    layers
}

/// A small TWN CNN matching the AOT-exported L2 model (python/compile/
/// model.py): used by the end-to-end example.
pub fn twn_cnn_layers(batch: usize) -> Vec<ConvLayer> {
    vec![
        ConvLayer { name: "twn_conv1", n: batch, c: 3, h: 32, w: 32, kn: 16, kh: 3, kw: 3, stride: 1, pad: 1 },
        ConvLayer { name: "twn_conv2", n: batch, c: 16, h: 32, w: 32, kn: 32, kh: 3, kw: 3, stride: 2, pad: 1 },
        ConvLayer { name: "twn_conv3", n: batch, c: 32, h: 16, w: 16, kn: 64, kh: 3, kw: 3, stride: 2, pad: 1 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer10_matches_table8_geometry() {
        let l = resnet18_layer10();
        assert_eq!((l.n, l.c, l.h, l.w), (5, 128, 28, 28));
        assert_eq!((l.kn, l.kh, l.kw, l.stride), (256, 3, 3, 2));
        assert_eq!(l.oh(), 14);
        assert_eq!(l.j_dim(), 1152); // 128 * 3 * 3
        assert_eq!(l.i_dim(), 196);
    }

    #[test]
    fn output_sizes_chain_correctly() {
        let layers = resnet18_conv_layers();
        assert_eq!(layers[0].oh(), 112); // 224/2
        assert_eq!(layers[1].oh(), 56);
        assert_eq!(layers[5].oh(), 28); // conv3_1a stride 2
        assert_eq!(layers[13].oh(), 7); // conv5_1a stride 2
    }

    #[test]
    fn macs_are_plausible() {
        // ResNet-18 (batch 1) is ~1.8 GMACs; our batch-5 3x3 backbone
        // (no FC / downsample convs) should land in the same ballpark x5.
        let total: u64 = resnet18_conv_layers().iter().map(|l| l.macs() / 5).sum();
        assert!(
            (1.0e9..2.5e9).contains(&(total as f64)),
            "total MACs {total}"
        );
    }

    #[test]
    fn scaled_table_chains_layer_to_layer() {
        for (input, div) in [(32, 8), (16, 16), (64, 4)] {
            let layers = resnet18_conv_layers_scaled(2, input, div);
            assert_eq!(layers.len(), 17, "div {div}");
            // conv1 feeds conv2 through the stem pool
            assert_eq!(layers[0].kn, layers[1].c);
            assert_eq!(layers[1].h, (layers[0].oh() / 2).max(1));
            // every later layer consumes its predecessor exactly
            for w in layers.windows(2).skip(1) {
                assert_eq!(w[0].kn, w[1].c, "{} -> {}", w[0].name, w[1].name);
                assert_eq!(w[0].oh(), w[1].h, "{} -> {}", w[0].name, w[1].name);
                assert_eq!(w[0].ow(), w[1].w, "{} -> {}", w[0].name, w[1].name);
            }
            for l in &layers {
                assert!(l.oh() >= 1 && l.ow() >= 1, "{} collapses", l.name);
            }
        }
    }

    #[test]
    fn scaled_table_at_unit_scale_matches_imagenet_geometry() {
        let full = resnet18_conv_layers();
        let scaled = resnet18_conv_layers_scaled(5, 224, 1);
        for (a, b) in full.iter().zip(&scaled) {
            assert_eq!((a.c, a.h, a.w, a.kn, a.stride), (b.c, b.h, b.w, b.kn, b.stride), "{}", a.name);
        }
    }

    #[test]
    fn twn_cnn_shapes_match_l2_model() {
        let layers = twn_cnn_layers(4);
        assert_eq!(layers[0].oh(), 32);
        assert_eq!(layers[1].oh(), 16);
        assert_eq!(layers[2].oh(), 8);
        assert_eq!(layers[2].kn, 64);
    }
}
