//! A minimal 4-D tensor (NCHW) over `f32`.

/// Dense NCHW tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor4 {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor4 {
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> Self {
        Self { n, c, h, w, data: vec![0.0; n * c * h * w] }
    }

    pub fn from_vec(n: usize, c: usize, h: usize, w: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * c * h * w, "shape/data mismatch");
        Self { n, c, h, w, data }
    }

    pub fn shape(&self) -> (usize, usize, usize, usize) {
        (self.n, self.c, self.h, self.w)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, n: usize, c: usize, h: usize, w: usize) -> usize {
        debug_assert!(n < self.n && c < self.c && h < self.h && w < self.w);
        ((n * self.c + c) * self.h + h) * self.w + w
    }

    #[inline]
    pub fn get(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        self.data[self.idx(n, c, h, w)]
    }

    #[inline]
    pub fn set(&mut self, n: usize, c: usize, h: usize, w: usize, v: f32) {
        let i = self.idx(n, c, h, w);
        self.data[i] = v;
    }

    /// Padded read: returns 0.0 outside the spatial extent.
    #[inline]
    pub fn get_padded(&self, n: usize, c: usize, h: isize, w: isize) -> f32 {
        if h < 0 || w < 0 || h as usize >= self.h || w as usize >= self.w {
            0.0
        } else {
            self.get(n, c, h as usize, w as usize)
        }
    }

    /// Fill with integer values from an RNG (exact under f32 addition).
    pub fn fill_random_ints(&mut self, rng: &mut crate::testutil::Rng, lo: i64, hi: i64) {
        for v in &mut self.data {
            *v = rng.irange(lo, hi) as f32;
        }
    }

    /// Fill with quantization-friendly values in [0, 1] (`k / 255`) — the
    /// request convention of the serving paths: the DPU's entry
    /// requantization at scale 255 recovers the integers exactly.
    pub fn fill_random_unit(&mut self, rng: &mut crate::testutil::Rng) {
        for v in &mut self.data {
            *v = rng.below(256) as f32 / 255.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_row_major_nchw() {
        let mut t = Tensor4::zeros(2, 3, 4, 5);
        t.set(1, 2, 3, 4, 7.0);
        assert_eq!(t.data[((1 * 3 + 2) * 4 + 3) * 5 + 4], 7.0);
        assert_eq!(t.get(1, 2, 3, 4), 7.0);
    }

    #[test]
    fn padded_reads_are_zero_outside() {
        let mut t = Tensor4::zeros(1, 1, 2, 2);
        t.set(0, 0, 0, 0, 5.0);
        assert_eq!(t.get_padded(0, 0, -1, 0), 0.0);
        assert_eq!(t.get_padded(0, 0, 0, 2), 0.0);
        assert_eq!(t.get_padded(0, 0, 0, 0), 5.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_len() {
        Tensor4::from_vec(1, 1, 2, 2, vec![0.0; 3]);
    }
}
