//! Workload geometry builders beyond ResNet-style conv chains: the two
//! genuinely different compute shapes the op IR exists for.
//!
//! - [`ternary_transformer_block`] — one transformer block as ternary
//!   GEMMs through the SACU path: a fused QKV projection (with the
//!   multi-head attention-score epilogue on the DPU), the output
//!   projection, and the two FFN matmuls.  FATNN (see PAPERS.md) argues
//!   ternary quantizes transformers well; here the whole block is four
//!   [`GemmLayer`]s against resident 2-bit registers.
//! - [`mobilenet_style_backbone`] — alternating depthwise/pointwise
//!   stages.  Depthwise convs stress the mapper the opposite way from
//!   3x3 ResNet convs: tiny per-group KN and reduction length, many
//!   small layers.
//!
//! These return geometry only ([`WorkloadLayer`]: an op plus its
//! epilogue flags); `coordinator::model::ModelSpec::synthetic_ops`
//! attaches synthetic ternary weights and folded BN to make a servable
//! model (`ModelSpec::synthetic_transformer` / `synthetic_mobilenet`).

use crate::nn::ops::{GemmLayer, GroupedConvLayer, LayerOp};
use crate::nn::resnet::ConvLayer;

/// One layer of a workload: the op, plus the epilogue the DPU applies
/// after BN + ReLU (multi-head attention scores and/or the 2x2 max
/// pool).  Pure geometry — no weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkloadLayer {
    pub op: LayerOp,
    /// `Some(heads)` applies the multi-head attention-score epilogue:
    /// the op's 3d output channels are read as fused Q/K/V and reduced
    /// to d attended channels.
    pub attn_heads: Option<usize>,
    /// Apply the DPU's 2x2/s2 max pool after BN + ReLU.
    pub pool_after: bool,
}

impl WorkloadLayer {
    /// A layer with no epilogue beyond BN + ReLU.
    pub fn plain(op: LayerOp) -> Self {
        Self { op, attn_heads: None, pool_after: false }
    }
}

/// One ternary transformer block over a `seq x d_model` activation
/// (carried as a `(1, d_model, seq, 1)` tensor: channels are features,
/// spatial is the token axis).  Four ternary GEMMs: fused QKV (3d
/// outputs + attention epilogue folding them back to d), the output
/// projection, and an `ffn_mult`-wide FFN up/down pair.
pub fn ternary_transformer_block(
    seq: usize,
    d_model: usize,
    heads: usize,
    ffn_mult: usize,
) -> Vec<WorkloadLayer> {
    assert!(seq > 0 && d_model > 0 && ffn_mult >= 1, "degenerate transformer block");
    assert!(heads >= 1 && d_model % heads == 0, "d_model must divide into heads");
    let gemm = |name: &'static str, k: usize, n: usize| {
        LayerOp::Gemm(GemmLayer { name, b: 1, m: seq, k, n })
    };
    vec![
        WorkloadLayer {
            op: gemm("qkv", d_model, 3 * d_model),
            attn_heads: Some(heads),
            pool_after: false,
        },
        WorkloadLayer::plain(gemm("proj", d_model, d_model)),
        WorkloadLayer::plain(gemm("ffn_up", d_model, ffn_mult * d_model)),
        WorkloadLayer::plain(gemm("ffn_down", ffn_mult * d_model, d_model)),
    ]
}

/// A MobileNet-style backbone: a 3x3/s2 stem, then four depthwise /
/// pointwise stage pairs with stride-2 downsampling (and channel
/// doubling) on alternating stages.  `width` is the stem's output
/// channel count; the deepest stage carries `8 * width` channels.
pub fn mobilenet_style_backbone(batch: usize, input_hw: usize, width: usize) -> Vec<WorkloadLayer> {
    assert!(batch > 0 && width >= 2, "degenerate backbone");
    assert!(input_hw >= 8, "input too small for three downsamples");
    let stem = ConvLayer {
        name: "stem",
        n: batch,
        c: 3,
        h: input_hw,
        w: input_hw,
        kn: width,
        kh: 3,
        kw: 3,
        stride: 2,
        pad: 1,
    };
    let mut h = stem.oh();
    let mut c = width;
    let mut out = vec![WorkloadLayer::plain(LayerOp::Conv(stem))];
    // (depthwise name, pointwise name, depthwise stride, channel mult)
    let stages: [(&'static str, &'static str, usize, usize); 4] = [
        ("dw1", "pw1", 1, 2),
        ("dw2", "pw2", 2, 2),
        ("dw3", "pw3", 1, 1),
        ("dw4", "pw4", 2, 2),
    ];
    for (dw_name, pw_name, stride, mult) in stages {
        let base = ConvLayer {
            name: dw_name,
            n: batch,
            c,
            h,
            w: h,
            kn: c,
            kh: 3,
            kw: 3,
            stride,
            pad: 1,
        };
        let dw = GroupedConvLayer::depthwise(dw_name, base);
        h = dw.unit().oh();
        out.push(WorkloadLayer::plain(LayerOp::GroupedConv(dw)));
        let pw = ConvLayer {
            name: pw_name,
            n: batch,
            c,
            h,
            w: h,
            kn: c * mult,
            kh: 1,
            kw: 1,
            stride: 1,
            pad: 0,
        };
        out.push(WorkloadLayer::plain(LayerOp::Conv(pw)));
        c = pw.kn;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_block_chains_feature_dims() {
        let ws = ternary_transformer_block(16, 8, 2, 4);
        assert_eq!(ws.len(), 4);
        // qkv: d -> 3d, folded back to d by the attention epilogue
        assert_eq!(ws[0].op.in_geometry(), (1, 8, 16, 1));
        assert_eq!(ws[0].op.kn(), 24);
        assert_eq!(ws[0].attn_heads, Some(2));
        // proj consumes the d attended channels
        assert_eq!(ws[1].op.in_geometry(), (1, 8, 16, 1));
        assert_eq!(ws[2].op.kn(), 32, "ffn_up widens by ffn_mult");
        assert_eq!(ws[3].op.in_geometry().1, 32);
        assert_eq!(ws[3].op.kn(), 8, "block output returns to d_model");
        for w in &ws {
            assert_eq!(w.op.out_geometry().2, 16, "token axis survives every GEMM");
        }
    }

    #[test]
    fn mobilenet_backbone_alternates_and_chains() {
        let ws = mobilenet_style_backbone(2, 16, 8);
        assert_eq!(ws.len(), 9, "stem + 4 x (dw, pw)");
        let mut prev_out: Option<(usize, usize, usize, usize)> = None;
        for w in &ws {
            let (n, c, h, ww) = w.op.in_geometry();
            if let Some((pn, pc, ph, pw)) = prev_out {
                assert_eq!((n, c, h, ww), (pn, pc, ph, pw), "{} chains", w.op.name());
            }
            let (on, oc, oh, ow) = w.op.out_geometry();
            prev_out = Some((on, oc, oh, ow));
        }
        // depthwise layers are grouped, pointwise are plain 1x1 convs
        assert!(matches!(ws[1].op, LayerOp::GroupedConv(_)));
        match ws[2].op {
            LayerOp::Conv(l) => assert_eq!((l.kh, l.kw), (1, 1)),
            _ => panic!("pw must be a plain conv"),
        }
        // three stride-2 points: 16 -> 8 (stem) -> 4 (dw2) -> 2 (dw4)
        assert_eq!(ws.last().unwrap().op.out_geometry().2, 2);
        assert_eq!(ws.last().unwrap().op.kn(), 64, "8 * width deep end");
    }
}
