//! Aligned-column / markdown table rendering used by benches and examples.
//!
//! Every bench target regenerates one of the paper's tables or figures;
//! this module renders them uniformly so `cargo bench` output reads like
//! the evaluation section.

/// A simple table builder: header row + data rows, rendered right-aligned
/// for numeric-looking cells and left-aligned otherwise.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavoured markdown (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with `digits` decimals, trimming to a compact cell.
pub fn fnum(v: f64, digits: usize) -> String {
    // normalize negative zero so empty breakdowns print as 0.000
    format!("{:.digits$}", v + 0.0)
}

/// Format a ratio as `N.NNx`.
pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format a large count with SI-ish suffix (K/M/G).
pub fn count(v: u64) -> String {
    match v {
        0..=9_999 => format!("{v}"),
        10_000..=999_999 => format!("{:.2}K", v as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.2}M", v as f64 / 1e6),
        _ => format!("{:.2}G", v as f64 / 1e9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "123.45".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        // aligned: the header "value" and "123.45" end at the same column
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[4].len());
    }

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("md", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render_markdown();
        assert!(s.contains("| a | b |"));
        assert!(s.contains("|---|---|"));
        assert!(s.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("bad", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn count_suffixes() {
        assert_eq!(count(999), "999");
        assert_eq!(count(3_290_000), "3.29M");
        assert_eq!(count(12_000), "12.00K");
    }

    #[test]
    fn ratio_format() {
        assert_eq!(ratio(10.024), "10.02x");
    }
}
