//! The PJRT execution engine.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Parsed dtype[shape] signature from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Signature {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl Signature {
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| anyhow!("bad signature `{s}`"))?;
        let dims = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("bad signature `{s}`"))?;
        let shape = if dims.is_empty() {
            vec![]
        } else {
            dims.split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Self { dtype: dtype.to_string(), shape })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub inputs: Vec<Signature>,
    pub outputs: Vec<Signature>,
    pub path: PathBuf,
}

/// Parse `artifacts/manifest.txt`.
pub fn parse_manifest(dir: &Path) -> Result<Vec<ArtifactInfo>> {
    let text = std::fs::read_to_string(dir.join("manifest.txt"))
        .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|line| {
            let mut parts = line.split('|');
            let name = parts.next().ok_or_else(|| anyhow!("empty line"))?.to_string();
            let ins = parts.next().and_then(|p| p.strip_prefix("in=")).unwrap_or("");
            let outs = parts.next().and_then(|p| p.strip_prefix("out=")).unwrap_or("");
            let parse_sigs = |s: &str| -> Result<Vec<Signature>> {
                if s.is_empty() {
                    return Ok(vec![]);
                }
                s.split(';').map(Signature::parse).collect()
            };
            Ok(ArtifactInfo {
                path: dir.join(format!("{name}.hlo.txt")),
                name,
                inputs: parse_sigs(ins)?,
                outputs: parse_sigs(outs)?,
            })
        })
        .collect()
}

/// The engine: a PJRT CPU client plus compiled executables by name.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: HashMap<String, (ArtifactInfo, xla::PjRtLoadedExecutable)>,
}

impl Engine {
    /// Default artifact directory: `$FAT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let mut artifacts = HashMap::new();
        for info in parse_manifest(dir)? {
            let proto = xla::HloModuleProto::from_text_file(
                info.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {:?}: {e:?}", info.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", info.name))?;
            artifacts.insert(info.name.clone(), (info, exe));
        }
        Ok(Self { client, artifacts })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.get(name).map(|(i, _)| i)
    }

    /// Execute an artifact with f32 inputs; returns the flattened first
    /// output (all exported functions return 1-tuples).
    pub fn run_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let (info, exe) = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        if inputs.len() != info.inputs.len() {
            bail!(
                "`{name}` wants {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&info.inputs)
            .enumerate()
            .map(|(i, (buf, sig))| {
                if buf.len() != sig.elements() {
                    bail!(
                        "`{name}` input {i}: want {} elements ({:?}), got {}",
                        sig.elements(),
                        sig.shape,
                        buf.len()
                    );
                }
                let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input {i}: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing `{name}`: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        // exported with return_tuple=True -> unwrap the 1-tuple
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signature_parsing() {
        let s = Signature::parse("f32[128,288]").unwrap();
        assert_eq!(s.dtype, "f32");
        assert_eq!(s.shape, vec![128, 288]);
        assert_eq!(s.elements(), 128 * 288);
        let scalar = Signature::parse("f32[]").unwrap();
        assert_eq!(scalar.shape, Vec::<usize>::new());
        assert_eq!(scalar.elements(), 1);
        assert!(Signature::parse("garbage").is_err());
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("fat_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "gemm|in=f32[2,3];f32[3,4]|out=f32[2,4]\n",
        )
        .unwrap();
        let infos = parse_manifest(&dir).unwrap();
        assert_eq!(infos.len(), 1);
        assert_eq!(infos[0].name, "gemm");
        assert_eq!(infos[0].inputs.len(), 2);
        assert_eq!(infos[0].outputs[0].shape, vec![2, 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = parse_manifest(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
