//! The PJRT execution engine.
//!
//! Artifact discovery (manifest + dtype/shape signatures) is fully
//! implemented and tested: [`Engine::load`] parses the artifact set and
//! serves metadata (`names`/`info`), and [`Engine::run_f32`] validates
//! input shapes.  Actual XLA *execution* needs the `xla` crate, which
//! the offline image does not carry, so `run_f32` reports PJRT as
//! unavailable with a clear error instead of failing to link.  Callers
//! that execute artifacts gate on [`Engine::backend_available`] (the
//! integration tests) or handle the `run_f32` error (the examples), so
//! the simulator-side paths keep working everywhere.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{anyhow, bail, Context, Result};

/// Parsed dtype[shape] signature from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Signature {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl Signature {
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| anyhow!("bad signature `{s}`"))?;
        let dims = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("bad signature `{s}`"))?;
        let shape = if dims.is_empty() {
            vec![]
        } else {
            dims.split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Self { dtype: dtype.to_string(), shape })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub inputs: Vec<Signature>,
    pub outputs: Vec<Signature>,
    pub path: PathBuf,
}

/// Parse `artifacts/manifest.txt`.
pub fn parse_manifest(dir: &Path) -> Result<Vec<ArtifactInfo>> {
    let text = std::fs::read_to_string(dir.join("manifest.txt"))
        .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|line| {
            let mut parts = line.split('|');
            let name = parts.next().ok_or_else(|| anyhow!("empty line"))?.to_string();
            let ins = parts.next().and_then(|p| p.strip_prefix("in=")).unwrap_or("");
            let outs = parts.next().and_then(|p| p.strip_prefix("out=")).unwrap_or("");
            let parse_sigs = |s: &str| -> Result<Vec<Signature>> {
                if s.is_empty() {
                    return Ok(vec![]);
                }
                s.split(';').map(Signature::parse).collect()
            };
            Ok(ArtifactInfo {
                path: dir.join(format!("{name}.hlo.txt")),
                name,
                inputs: parse_sigs(ins)?,
                outputs: parse_sigs(outs)?,
            })
        })
        .collect()
}

/// The engine: artifact metadata by name, plus (when an XLA backend is
/// vendored) the compiled executables.  Without a backend, [`Engine::load`]
/// fails with a clear message after validating the artifact set.
pub struct Engine {
    artifacts: HashMap<String, ArtifactInfo>,
}

impl Engine {
    /// Whether this build can actually execute artifacts.  `false` until
    /// an XLA/PJRT backend is vendored — callers that want to *run*
    /// artifacts (rather than inspect metadata) gate on this and skip.
    pub fn backend_available() -> bool {
        false
    }

    /// Default artifact directory: `$FAT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load every artifact in `dir`.  Parses and validates the manifest
    /// (so `info`/`names` work and shape errors are caught), but actual
    /// execution needs an XLA backend — [`Engine::run_f32`] reports it
    /// unavailable in this build.
    pub fn load(dir: &Path) -> Result<Self> {
        let infos = parse_manifest(dir)?;
        let artifacts: HashMap<String, ArtifactInfo> =
            infos.into_iter().map(|i| (i.name.clone(), i)).collect();
        Ok(Self { artifacts })
    }

    pub fn platform(&self) -> String {
        "unavailable (no PJRT backend in this build)".to_string()
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.get(name)
    }

    /// Execute an artifact with f32 inputs.  Validates shapes against the
    /// manifest signatures, then bails (no backend in this build).
    pub fn run_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let info = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        if inputs.len() != info.inputs.len() {
            bail!(
                "`{name}` wants {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            );
        }
        for (i, (buf, sig)) in inputs.iter().zip(&info.inputs).enumerate() {
            if buf.len() != sig.elements() {
                bail!(
                    "`{name}` input {i}: want {} elements ({:?}), got {}",
                    sig.elements(),
                    sig.shape,
                    buf.len()
                );
            }
        }
        bail!("PJRT runtime unavailable: cannot execute `{name}` in this build");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signature_parsing() {
        let s = Signature::parse("f32[128,288]").unwrap();
        assert_eq!(s.dtype, "f32");
        assert_eq!(s.shape, vec![128, 288]);
        assert_eq!(s.elements(), 128 * 288);
        let scalar = Signature::parse("f32[]").unwrap();
        assert_eq!(scalar.shape, Vec::<usize>::new());
        assert_eq!(scalar.elements(), 1);
        assert!(Signature::parse("garbage").is_err());
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("fat_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "gemm|in=f32[2,3];f32[3,4]|out=f32[2,4]\n",
        )
        .unwrap();
        let infos = parse_manifest(&dir).unwrap();
        assert_eq!(infos.len(), 1);
        assert_eq!(infos[0].name, "gemm");
        assert_eq!(infos[0].inputs.len(), 2);
        assert_eq!(infos[0].outputs[0].shape, vec![2, 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = parse_manifest(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn stub_engine_serves_metadata_but_not_execution() {
        let dir = std::env::temp_dir().join("fat_engine_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "gemm|in=f32[2,2]|out=f32[2]\n").unwrap();
        assert!(!Engine::backend_available(), "no xla backend in this build");
        let engine = Engine::load(&dir).unwrap();
        assert_eq!(engine.names(), vec!["gemm"]);
        assert_eq!(engine.info("gemm").unwrap().inputs[0].shape, vec![2, 2]);
        // shape validation still happens before the backend check
        let shape_err = engine.run_f32("gemm", &[vec![0.0; 3]]).unwrap_err();
        assert!(format!("{shape_err:#}").contains("want 4 elements"));
        let err = engine.run_f32("gemm", &[vec![0.0; 4]]).unwrap_err();
        assert!(format!("{err:#}").contains("PJRT runtime unavailable"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
