//! PJRT runtime bridge — loads the AOT artifacts, executes them from rust.
//!
//! Python runs once at build time (`make artifacts`); at run time the rust
//! binary loads HLO *text* (`artifacts/*.hlo.txt`), compiles it on the
//! PJRT CPU client via the `xla` crate, and executes with concrete
//! buffers.  HLO text is the interchange format because jax >= 0.5 emits
//! HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod verify;

pub use engine::{Engine, Signature};
