//! PJRT runtime bridge — loads the AOT artifacts, executes them from rust.
//!
//! Python runs once at build time (`make artifacts`); at run time the rust
//! binary loads HLO *text* (`artifacts/*.hlo.txt`) and would compile it on
//! the PJRT CPU client.  The offline image carries no `xla` crate, so the
//! engine validates the artifact set and reports PJRT as unavailable; all
//! callers treat that as "skip the cross-check" and the simulator paths
//! remain fully functional.  HLO text stays the interchange format because
//! jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that older
//! xla_extension builds reject; a text parser can reassign ids.

pub mod engine;
pub mod verify;

pub use engine::{Engine, Signature};
