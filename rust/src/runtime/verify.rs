//! Cross-validation: simulator vs XLA execution of the L2/L1 graphs.
//!
//! The TWN path is exact over integer-valued f32 (sums stay far below
//! 2^24), so the bit-serial simulator and the XLA-executed Pallas kernel
//! must agree **bit for bit** on the GEMM; the full CNN (float BN) is
//! compared with a tolerance.

use crate::error::{bail, Result};

use crate::coordinator::accelerator::{ChipConfig, FatChip};
use crate::nn::layers::TernaryFilter;
use crate::nn::resnet::ConvLayer;
use crate::nn::tensor::Tensor4;
use crate::testutil::Rng;

use super::engine::Engine;

/// Outcome of one cross-check.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub name: String,
    pub elements: usize,
    pub max_abs_err: f32,
    pub exact: bool,
}

/// Cross-check the `ternary_gemm` artifact against the bit-serial chip.
///
/// Generates integer activations and ternary weights at `sparsity`, runs
/// the XLA-compiled Pallas kernel and the simulated chip on the same
/// GEMM, and demands exact agreement.
pub fn verify_ternary_gemm(engine: &Engine, seed: u64, sparsity: f64) -> Result<VerifyReport> {
    let info = engine
        .info("ternary_gemm")
        .ok_or_else(|| crate::anyhow!("artifact `ternary_gemm` missing"))?;
    let (m, k) = (info.inputs[0].shape[0], info.inputs[0].shape[1]);
    let n = info.inputs[1].shape[1];

    let mut rng = Rng::new(seed);
    let x: Vec<f32> = rng.int_f32_vec(m * k, 0, 256);
    let w: Vec<i8> = rng.ternary_vec(k * n, sparsity);
    let w_f32: Vec<f32> = w.iter().map(|&v| v as f32).collect();

    // XLA path: the AOT-compiled L1 Pallas kernel.
    let xla_out = engine.run_f32("ternary_gemm", &[x.clone(), w_f32])?;

    // Simulator path: the GEMM is a 1x1 "convolution" over C=k channels
    // with kn=n filters and a 1-pixel image per row of x... simpler: treat
    // each output column as a conv layer is overkill — reuse the chip on a
    // synthetic layer of geometry (N=m, C=k, H=W=1, KN=n, 1x1 kernel).
    let layer = ConvLayer {
        name: "gemm", n: m, c: k, h: 1, w: 1, kn: n, kh: 1, kw: 1, stride: 1, pad: 0,
    };
    let xt = Tensor4::from_vec(m, k, 1, 1, x);
    let mut wt = vec![0i8; n * k];
    // x @ w uses w[k][n]; the filter layout is (KN, C) = (n, k)
    for kk in 0..k {
        for nn in 0..n {
            wt[nn * k + kk] = w[kk * n + nn];
        }
    }
    let filter = TernaryFilter::new(n, k, 1, 1, wt);
    let chip = FatChip::new(ChipConfig::fat());
    let run = chip.run_conv_layer(&xt, &filter, &layer);

    let mut max_err = 0.0f32;
    for row in 0..m {
        for col in 0..n {
            let sim = run.output.get(row, col, 0, 0);
            let xla = xla_out[row * n + col];
            max_err = max_err.max((sim - xla).abs());
        }
    }
    if max_err != 0.0 {
        bail!("ternary_gemm mismatch: max abs err {max_err}");
    }
    Ok(VerifyReport {
        name: "ternary_gemm".into(),
        elements: m * n,
        max_abs_err: max_err,
        exact: true,
    })
}

/// Compare two f32 buffers with a tolerance; returns max abs error.
pub fn compare(a: &[f32], b: &[f32], atol: f32) -> Result<f32> {
    if a.len() != b.len() {
        bail!("length mismatch: {} vs {}", a.len(), b.len());
    }
    let mut max_err = 0.0f32;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs();
        if err > atol {
            bail!("element {i}: {x} vs {y} (|err| {err} > atol {atol})");
        }
        max_err = max_err.max(err);
    }
    Ok(max_err)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_accepts_close_and_rejects_far() {
        assert!(compare(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
        assert!(compare(&[1.0], &[1.1], 1e-5).is_err());
        assert!(compare(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }
}
