//! Ternary Weight Network substrate: quantization, packing, sparsity.
//!
//! - Eq. (7): threshold ternarization `w -> {+1, 0, -1}`.
//! - 2-bit packing: the 16x storage saving over FP32 of Table I, without a
//!   compressed sparse format (the paper's argument in §I: CSR-style
//!   formats store 8-bit indices per 2-bit non-zero and *lose* on TWNs).
//! - Sparsity statistics the SACU exploits, and the BWN extension
//!   (§III-B1: binary weights become {+1, -1} 2-bit codes, zero benefit
//!   from sparsity).

use crate::testutil::Rng;

/// Eq. (7): threshold ternarization of one weight.
pub fn ternarize(w: f32, th_low: f32, th_high: f32) -> i8 {
    assert!(th_low < th_high, "TH_low must be below TH_high");
    if w > th_high {
        1
    } else if w < th_low {
        -1
    } else {
        0
    }
}

/// Ternarize a whole tensor.
pub fn ternarize_all(ws: &[f32], th_low: f32, th_high: f32) -> Vec<i8> {
    ws.iter().map(|&w| ternarize(w, th_low, th_high)).collect()
}

/// Symmetric thresholds from the TWN heuristic `th = 0.7 * mean(|w|)`
/// (Li et al. [11]).
pub fn twn_threshold(ws: &[f32]) -> f32 {
    if ws.is_empty() {
        return 0.0;
    }
    0.7 * ws.iter().map(|w| w.abs()).sum::<f32>() / ws.len() as f32
}

/// Fraction of zero weights — what the SACU can skip.
pub fn sparsity(ws: &[i8]) -> f64 {
    if ws.is_empty() {
        return 0.0;
    }
    ws.iter().filter(|&&w| w == 0).count() as f64 / ws.len() as f64
}

/// Generate synthetic ternary weights at a controlled sparsity (the
/// Fig. 14 workloads: the paper's speedups depend only on this knob).
pub fn synthetic_weights(rng: &mut Rng, n: usize, target_sparsity: f64) -> Vec<i8> {
    rng.ternary_vec(n, target_sparsity)
}

/// Extend 1-bit binary weights {+1, -1} to the 2-bit ternary encoding —
/// the BWN configuration of §III-B1.
pub fn bwn_extend(ws: &[bool]) -> Vec<i8> {
    ws.iter().map(|&plus| if plus { 1 } else { -1 }).collect()
}

/// Storage cost of a weight tensor under different representations, bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorageCost {
    pub fp32: usize,
    pub int8: usize,
    pub int4: usize,
    /// The FAT representation: dense 2-bit codes.
    pub ternary_2bit: usize,
    /// CSR-style: 2-bit values + 8-bit indices for the non-zeros.
    pub csr_sparse: usize,
    /// 1-bit binary (BWN).
    pub binary_1bit: usize,
}

/// Table I storage analysis for a weight tensor.
pub fn storage_cost(ws: &[i8]) -> StorageCost {
    let n = ws.len();
    let nnz = ws.iter().filter(|&&w| w != 0).count();
    StorageCost {
        fp32: 4 * n,
        int8: n,
        int4: n.div_ceil(2),
        ternary_2bit: (2 * n).div_ceil(8),
        // 2-bit value + 8-bit delta index per non-zero, bit-packed
        csr_sparse: (10 * nnz).div_ceil(8),
        binary_1bit: n.div_ceil(8),
    }
}

/// Operation count of a dot product of length `n` under each quantization
/// (Table I "Operator" column): multiplies for FP/INT8/INT4, additions for
/// TWN/BWN, with TWN skipping the zeros.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpCount {
    pub multiplies: usize,
    pub additions: usize,
}

pub fn dot_op_count(ws: &[i8], quantization: &str) -> OpCount {
    let n = ws.len();
    let nnz = ws.iter().filter(|&&w| w != 0).count();
    match quantization {
        "fp32" | "int8" | "int4" => OpCount { multiplies: n, additions: n - 1 },
        // BWN: every weight is +-1 -> n additions/subtractions
        "bwn" => OpCount { multiplies: 0, additions: n },
        // TWN on FAT: only the non-zeros are touched
        "twn" => OpCount { multiplies: 0, additions: nnz },
        other => panic!("unknown quantization {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop_check;

    #[test]
    fn eq7_thresholds() {
        assert_eq!(ternarize(0.5, -0.3, 0.3), 1);
        assert_eq!(ternarize(-0.5, -0.3, 0.3), -1);
        assert_eq!(ternarize(0.0, -0.3, 0.3), 0);
        assert_eq!(ternarize(0.3, -0.3, 0.3), 0, "boundary is 0 (strict >)");
        assert_eq!(ternarize(-0.3, -0.3, 0.3), 0, "boundary is 0 (strict <)");
    }

    #[test]
    #[should_panic(expected = "TH_low must be below TH_high")]
    fn rejects_inverted_thresholds() {
        ternarize(0.0, 0.3, -0.3);
    }

    #[test]
    fn property_output_is_ternary_and_monotone() {
        prop_check(
            "ternarize in {-1,0,1}, monotone in w",
            200,
            7,
            |rng| (rng.f32_range(-2.0, 2.0), rng.f32_range(-2.0, 2.0)),
            |&(w1, w2)| {
                let (lo, hi) = (-0.25f32, 0.25f32);
                let (t1, t2) = (ternarize(w1, lo, hi), ternarize(w2, lo, hi));
                if !(-1..=1).contains(&t1) {
                    return Err(format!("{t1} not ternary"));
                }
                if w1 <= w2 && t1 > t2 {
                    return Err(format!("not monotone: {w1}->{t1}, {w2}->{t2}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn twn_threshold_scales_with_magnitude() {
        let small = twn_threshold(&[0.1, -0.1, 0.1, -0.1]);
        let large = twn_threshold(&[1.0, -1.0, 1.0, -1.0]);
        assert!((small - 0.07).abs() < 1e-6);
        assert!((large - 0.7).abs() < 1e-6);
        assert_eq!(twn_threshold(&[]), 0.0);
    }

    #[test]
    fn sparsity_counts_zeros() {
        assert_eq!(sparsity(&[0, 0, 1, -1]), 0.5);
        assert_eq!(sparsity(&[]), 0.0);
        assert_eq!(sparsity(&bwn_extend(&[true, false])), 0.0);
    }

    #[test]
    fn storage_matches_table1_ratios() {
        let ws = vec![1i8; 1024];
        let c = storage_cost(&ws);
        assert_eq!(c.fp32, 4096);
        assert_eq!(c.ternary_2bit, 256); // 16x smaller than FP32
        assert_eq!(c.fp32 / c.ternary_2bit, 16);
        assert_eq!(c.binary_1bit, 128); // 32x
        assert_eq!(c.int8, 1024);
    }

    #[test]
    fn csr_loses_on_moderately_sparse_twn() {
        // the paper's §I argument: 8-bit indices per 2-bit non-zero make
        // CSR bigger than the dense 2-bit format unless extremely sparse
        let mut rng = Rng::new(3);
        let ws = synthetic_weights(&mut rng, 4096, 0.6);
        let c = storage_cost(&ws);
        assert!(
            c.csr_sparse > c.ternary_2bit,
            "CSR {} should exceed dense 2-bit {} at 60% sparsity",
            c.csr_sparse,
            c.ternary_2bit
        );
        // only at ~80%+ sparsity does CSR break even on storage
        let ws95 = synthetic_weights(&mut rng, 4096, 0.95);
        let c95 = storage_cost(&ws95);
        assert!(c95.csr_sparse < c95.ternary_2bit);
    }

    #[test]
    fn op_counts_follow_table1() {
        let ws: Vec<i8> = vec![1, 0, -1, 0, 0, 1, 0, 0, 0, 0]; // 70% sparse
        let fp = dot_op_count(&ws, "fp32");
        let twn = dot_op_count(&ws, "twn");
        let bwn = dot_op_count(&ws, "bwn");
        assert_eq!(fp.multiplies, 10);
        assert_eq!(twn.multiplies, 0);
        assert_eq!(twn.additions, 3, "only the non-zeros");
        assert_eq!(bwn.additions, 10, "BWN cannot skip");
    }

    #[test]
    fn synthetic_weights_hit_target_sparsity() {
        let mut rng = Rng::new(11);
        for target in [0.4, 0.6, 0.8] {
            let ws = synthetic_weights(&mut rng, 50_000, target);
            let got = sparsity(&ws);
            assert!((got - target).abs() < 0.01, "target {target} got {got}");
        }
    }
}
