//! Integration tests across the three layers.
//!
//! PJRT-dependent tests need `make artifacts`; they are skipped (with a
//! loud message) when the artifacts are absent so `cargo test` still runs
//! in a bare checkout, while `make test` always exercises them.

use fat_imc::coordinator::accelerator::{ChipConfig, FatChip};
use fat_imc::coordinator::scheduler::{analytic_compute_metrics, AnalyticConfig};
use fat_imc::mapping::schemes::MappingKind;
use fat_imc::nn::layers::{conv2d_ternary, TernaryFilter};
use fat_imc::nn::resnet::ConvLayer;
use fat_imc::nn::tensor::Tensor4;
use fat_imc::runtime::engine::Engine;
use fat_imc::runtime::verify::{compare, verify_ternary_gemm};
use fat_imc::testutil::Rng;

fn artifacts() -> Option<Engine> {
    if !Engine::backend_available() {
        eprintln!("SKIP (no PJRT backend in this build; execution tests need a vendored xla)");
        return None;
    }
    let dir = Engine::default_dir();
    match Engine::load(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP (no artifacts: run `make artifacts`): {err:#}");
            None
        }
    }
}

#[test]
fn pjrt_ternary_gemm_bit_exact_across_sparsities() {
    let Some(engine) = artifacts() else { return };
    for (seed, sparsity) in [(1u64, 0.0), (2, 0.4), (3, 0.8), (4, 1.0)] {
        let rep = verify_ternary_gemm(&engine, seed, sparsity).unwrap();
        assert!(rep.exact, "sparsity {sparsity}");
        assert_eq!(rep.max_abs_err, 0.0);
    }
}

#[test]
fn pjrt_dense_vs_ternary_gemm_agree_on_ternary_weights() {
    // the dense f32 GEMM baseline and the multiply-free ternary kernel
    // must agree when the weights are ternary
    let Some(engine) = artifacts() else { return };
    let info = engine.info("ternary_gemm").unwrap();
    let (m, k) = (info.inputs[0].shape[0], info.inputs[0].shape[1]);
    let n = info.inputs[1].shape[1];
    let mut rng = Rng::new(99);
    let x = rng.int_f32_vec(m * k, -64, 64);
    let w: Vec<f32> = rng.ternary_vec(k * n, 0.5).iter().map(|&v| v as f32).collect();
    let ternary = engine.run_f32("ternary_gemm", &[x.clone(), w.clone()]).unwrap();
    let dense = engine.run_f32("dense_gemm", &[x, w]).unwrap();
    let max_err = compare(&ternary, &dense, 1e-3).unwrap();
    assert_eq!(max_err, 0.0, "integer-valued f32 must be exact");
}

#[test]
fn pjrt_rejects_wrong_shapes() {
    let Some(engine) = artifacts() else { return };
    let err = engine.run_f32("ternary_gemm", &[vec![0.0; 7], vec![0.0; 7]]);
    assert!(err.is_err());
    assert!(engine.run_f32("nonexistent", &[]).is_err());
}

#[test]
fn chip_vs_reference_on_twn_cnn_layers() {
    // every layer geometry of the exported L2 model, bit-accurate
    let mut rng = Rng::new(0x17E6);
    for layer in fat_imc::nn::resnet::twn_cnn_layers(2) {
        let mut x = Tensor4::zeros(layer.n, layer.c, layer.h, layer.w);
        x.fill_random_ints(&mut rng, 0, 256);
        let f = TernaryFilter::new(
            layer.kn, layer.c, layer.kh, layer.kw,
            rng.ternary_vec(layer.kn * layer.j_dim(), 0.6),
        );
        let run = FatChip::new(ChipConfig::fat()).run_conv_layer(&x, &f, &layer);
        let want = conv2d_ternary(&x, &f, layer.stride, layer.pad);
        assert_eq!(run.output.data, want.data, "{}", layer.name);
    }
}

#[test]
fn bit_accurate_and_analytic_models_agree_on_direction() {
    // the analytic Fig.14 model and the bit-accurate simulator must agree
    // on who wins and roughly by how much at high sparsity
    let layer = ConvLayer {
        name: "xcheck", n: 1, c: 8, h: 10, w: 10, kn: 8, kh: 3, kw: 3, stride: 1, pad: 1,
    };
    let mut rng = Rng::new(5);
    let mut x = Tensor4::zeros(1, 8, 10, 10);
    x.fill_random_ints(&mut rng, 0, 256);
    let f = TernaryFilter::new(8, 8, 3, 3, rng.ternary_vec(8 * 72, 0.8));

    let fat = FatChip::new(ChipConfig::fat()).run_conv_layer(&x, &f, &layer);
    let para = FatChip::new(ChipConfig::parapim_baseline()).run_conv_layer(&x, &f, &layer);
    let sim_speedup = para.metrics.latency_ns / fat.metrics.latency_ns;

    let mut fat_cfg = AnalyticConfig::fat();
    let mut para_cfg = AnalyticConfig::parapim_baseline();
    fat_cfg.mapping = MappingKind::Img2ColIs;
    para_cfg.mapping = MappingKind::Img2ColIs;
    // compute-path comparison (loading costs are identical on both sides
    // and dominate this deliberately tiny layer)
    let ana_speedup = analytic_compute_metrics(&layer, 0.8, &para_cfg).latency_ns
        / analytic_compute_metrics(&layer, 0.8, &fat_cfg).latency_ns;

    assert!(sim_speedup > 3.0, "simulated speedup {sim_speedup}");
    assert!(ana_speedup > 3.0, "analytic speedup {ana_speedup}");
    // same direction and same order of magnitude
    let ratio = sim_speedup / ana_speedup;
    assert!((0.3..6.0).contains(&ratio), "sim {sim_speedup} vs analytic {ana_speedup}");
}

#[test]
fn sparsity_sweep_scales_simulated_speedup() {
    // more zeros -> more skipped -> faster, monotonically
    let layer = ConvLayer {
        name: "sweep", n: 1, c: 4, h: 8, w: 8, kn: 4, kh: 3, kw: 3, stride: 1, pad: 1,
    };
    let mut rng = Rng::new(6);
    let mut x = Tensor4::zeros(1, 4, 8, 8);
    x.fill_random_ints(&mut rng, 0, 256);
    let mut latencies = Vec::new();
    for s in [0.0, 0.4, 0.8] {
        let f = TernaryFilter::new(4, 4, 3, 3, rng.ternary_vec(4 * 36, s));
        let run = FatChip::new(ChipConfig::fat()).run_conv_layer(&x, &f, &layer);
        latencies.push(run.metrics.latency_ns);
    }
    assert!(latencies[0] > latencies[1], "{latencies:?}");
    assert!(latencies[1] > latencies[2], "{latencies:?}");
}

#[test]
fn cli_binary_smoke() {
    // run the built `fat` binary end to end (no artifacts needed for map)
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe).args(["map", "--layer", "10"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Img2Col-CS"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["infer", "--sparsity", "0.8", "--layer", "2"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("null ops skipped"));

    let out = std::process::Command::new(exe).args(["help"]).output().unwrap();
    assert!(out.status.success());

    // sweep: assert table *structure*, not a hardcoded speedup constant —
    // every data row must carry a `N.NNx` speedup column that parses to a
    // float > 1 (FAT must beat the baseline at every swept sparsity).
    let out = std::process::Command::new(exe)
        .args(["sweep", "--from", "0.4", "--to", "0.8", "--step", "0.2"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let speedups: Vec<f64> = text
        .lines()
        .filter(|l| l.trim_start().ends_with('x') && l.contains('%'))
        .map(|l| {
            let cells: Vec<&str> = l.split_whitespace().collect();
            assert_eq!(cells.len(), 5, "sweep row should have 5 columns: {l}");
            cells[3]
                .strip_suffix('x')
                .unwrap_or_else(|| panic!("speedup cell `{}` not `N.NNx`", cells[3]))
                .parse::<f64>()
                .unwrap_or_else(|_| panic!("speedup cell `{}` not a number", cells[3]))
        })
        .collect();
    assert_eq!(speedups.len(), 3, "expected one row per swept sparsity:\n{text}");
    for s in &speedups {
        assert!(*s > 1.0, "FAT must beat ParaPIM, got {s}x:\n{text}");
    }
    // higher sparsity -> more skipping -> larger speedup
    assert!(speedups.windows(2).all(|w| w[0] < w[1]), "{speedups:?}");

    // the weight-stationary end-to-end pipeline serves from the CLI
    let out = std::process::Command::new(exe)
        .args(["resnet", "--input", "16", "--scale", "16", "--requests", "2"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "resnet failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("one-time load"), "{text}");
    assert!(text.contains("loading vs compute"), "{text}");

    // fidelity flag: explicit bit-serial is accepted and reported; a
    // bogus value is a clean error
    let out = std::process::Command::new(exe)
        .args(["infer", "--sparsity", "0.8", "--layer", "2", "--fidelity", "bit-serial"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("BitSerial"));
    let out = std::process::Command::new(exe)
        .args(["infer", "--fidelity", "cycle-exactish"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("fidelity"));

    // unknown flags must be rejected
    let out = std::process::Command::new(exe).args(["infer", "--bogus", "1"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn cli_sharded_resnet_smoke() {
    // `fat resnet --shards N` serves the model as a chip pipeline, prints
    // the shard plan + transfer legs, and self-checks bit-exactness
    // against the single-chip oracle (a mismatch exits non-zero).
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe)
        .args(["resnet", "--input", "16", "--scale", "16", "--requests", "2", "--shards", "2"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "sharded resnet failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("shard plan over 2 chips"), "{text}");
    assert!(text.contains("register-write conservation"), "{text}");
    assert!(text.contains("bit-identical to the single-chip oracle"), "{text}");
    assert!(text.contains("on the link"), "{text}");

    // more shards than layers is a clean error, not a crash
    let out = std::process::Command::new(exe)
        .args(["resnet", "--layers", "2", "--shards", "5"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("shards"), "{err}");
}

#[test]
fn cli_plan_and_auto_smoke() {
    // `fat plan` profiles the layers and prints the latency-balanced
    // hybrid plan; `fat resnet --auto` serves it and self-checks
    // bit-exactness + register-write conservation against the oracle
    // (a divergence exits non-zero).
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe)
        .args(["plan", "--input", "16", "--scale", "16", "--chips", "3"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "plan failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("per-layer profile"), "{text}");
    assert!(text.contains("auto hybrid plan"), "{text}");
    assert!(text.contains("estimated issue interval"), "{text}");

    let out = std::process::Command::new(exe)
        .args([
            "resnet", "--auto", "--chips", "2", "--input", "16", "--scale", "16",
            "--requests", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "resnet --auto failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("register-write conservation"), "{text}");
    assert!(text.contains("bit-identical to the single-chip oracle"), "{text}");

    // --auto and --shards are mutually exclusive; --chips needs --auto
    let out = std::process::Command::new(exe)
        .args(["resnet", "--auto", "--shards", "2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = std::process::Command::new(exe)
        .args(["resnet", "--chips", "2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn cli_pipelined_batching_smoke() {
    // the sharded micro-batcher from the CLI: pipelined mode now takes
    // --max-batch and reports per-request metrics without deadlocking
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe)
        .args([
            "serve", "--mode", "pipelined", "--shards", "2", "--max-batch", "3",
            "--requests", "4", "--input", "16", "--scale", "16",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "pipelined --max-batch serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("micro-batch window 3"), "{text}");
    assert!(text.contains("inter-chip transfer total"), "{text}");
}

#[test]
fn cli_hybrid_serving_smoke() {
    // `fat serve --mode hybrid --chips N` plans with plan_auto and serves
    // on the threaded stage fabric; `fat resnet --auto --serve` replays
    // the auto plan through the same server and re-checks bit-identity
    // against the oracle (a divergence exits non-zero).
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe)
        .args([
            "serve", "--mode", "hybrid", "--chips", "2", "--max-batch", "2", "--requests",
            "3", "--input", "16", "--scale", "16",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "hybrid serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("auto hybrid plan"), "{text}");
    assert!(text.contains("hybrid pipeline over"), "{text}");
    assert!(text.contains("served 3 requests"), "{text}");

    let out = std::process::Command::new(exe)
        .args([
            "resnet", "--auto", "--chips", "2", "--serve", "--input", "16", "--scale",
            "16", "--requests", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "resnet --auto --serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("replaying the plan through the hybrid server"), "{text}");
    assert!(text.contains("bit-identical to the oracle"), "{text}");

    // flag discipline: hybrid plans its own stages; --serve needs --auto
    let out = std::process::Command::new(exe)
        .args(["serve", "--mode", "hybrid", "--shards", "2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("hybrid mode plans its own stages"), "{err}");
    let out = std::process::Command::new(exe)
        .args(["serve", "--mode", "replicated", "--chips", "2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = std::process::Command::new(exe).args(["resnet", "--serve"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--auto"), "{err}");
}

#[test]
fn cli_reliability_smoke() {
    // `fat reliability` sweeps accuracy-vs-BER through the serving stack
    // and self-checks that the zero-BER point is bit-identical to the
    // fault-free oracle (exits non-zero otherwise).  Tiny geometry: the
    // debug binary serves (points + 1) x requests inferences.
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe)
        .args([
            "reliability", "--input", "8", "--scale", "64", "--requests", "2",
            "--classes", "5", "--bers", "0,0.02",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "reliability failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("accuracy vs BER"), "{text}");
    assert!(text.contains("sense-margin map"), "{text}");
    assert!(
        text.contains("zero-BER self-check: bit-identical"),
        "the sweep must prove the injection plumbing is transparent at ber 0:\n{text}"
    );

    // replicated mode: a pool of decorrelated full-model replicas
    let out = std::process::Command::new(exe)
        .args([
            "reliability", "--input", "8", "--scale", "64", "--requests", "2",
            "--classes", "5", "--bers", "0,0.02", "--workers", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "replicated reliability failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("2-replica pool"), "{text}");
    assert!(text.contains("zero-BER self-check: bit-identical"), "{text}");

    // the pipelined sweep accepts link BERs; a link BER without shards is
    // a clean error, not a crash
    let out = std::process::Command::new(exe)
        .args([
            "reliability", "--input", "8", "--scale", "64", "--requests", "1",
            "--classes", "5", "--bers", "0,0.02", "--link-bers", "0,0.05",
            "--shards", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "pipelined reliability failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("2-shard pipeline"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["reliability", "--bers", "0", "--link-bers", "0.1"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "link BER without a pipeline must be rejected");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("link"), "{err}");

    // SECDED link ECC: accepted on a pipeline (and surfaced in the
    // report), a clean error without one
    let out = std::process::Command::new(exe)
        .args([
            "reliability", "--input", "8", "--scale", "64", "--requests", "1",
            "--classes", "5", "--bers", "0,0", "--link-bers", "0,0.01",
            "--shards", "2", "--link-ecc",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "ECC reliability failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("SECDED"));
    let out = std::process::Command::new(exe)
        .args(["reliability", "--bers", "0", "--link-ecc"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "link ECC without a pipeline must be rejected");
}

#[test]
fn bwn_mode_runs_binary_weights() {
    // §III-B1: FAT works as a BWN accelerator by extending 1-bit weights
    // to the 2-bit encoding — correct results, but nothing to skip.
    let layer = ConvLayer {
        name: "bwn", n: 1, c: 4, h: 8, w: 8, kn: 4, kh: 3, kw: 3, stride: 1, pad: 1,
    };
    let mut rng = Rng::new(0xB11);
    let mut x = Tensor4::zeros(1, 4, 8, 8);
    x.fill_random_ints(&mut rng, 0, 256);
    let bits: Vec<bool> = (0..4 * 36).map(|_| rng.chance(0.5)).collect();
    let w = fat_imc::ternary::bwn_extend(&bits);
    let f = TernaryFilter::new(4, 4, 3, 3, w);
    assert_eq!(f.sparsity(), 0.0, "BWN weights have no zeros");

    let run = FatChip::new(ChipConfig::fat()).run_conv_layer(&x, &f, &layer);
    let want = conv2d_ternary(&x, &f, 1, 1);
    assert_eq!(run.output.data, want.data);
    assert_eq!(run.metrics.skipped, 0, "no sparsity benefit in BWN mode");
}

#[test]
fn ternarized_float_weights_roundtrip_the_full_path() {
    // eq.(7) quantization feeding the chip: floats -> ternary -> conv
    let layer = ConvLayer {
        name: "quant", n: 1, c: 3, h: 6, w: 6, kn: 2, kh: 3, kw: 3, stride: 1, pad: 1,
    };
    let mut rng = Rng::new(0xB12);
    let raw: Vec<f32> = (0..2 * 27).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let th = fat_imc::ternary::twn_threshold(&raw);
    let w = fat_imc::ternary::ternarize_all(&raw, -th, th);
    let f = TernaryFilter::new(2, 3, 3, 3, w);
    let mut x = Tensor4::zeros(1, 3, 6, 6);
    x.fill_random_ints(&mut rng, 0, 256);
    let run = FatChip::new(ChipConfig::fat()).run_conv_layer(&x, &f, &layer);
    assert_eq!(run.output.data, conv2d_ternary(&x, &f, 1, 1).data);
    assert!(run.metrics.skipped > 0, "eq.(7) thresholds produce zeros to skip");
}

#[test]
fn all_four_sa_designs_drive_a_correct_layer() {
    // the chip is SA-design generic: every scheme computes the same layer
    let layer = ConvLayer {
        name: "all-sa", n: 1, c: 3, h: 6, w: 6, kn: 3, kh: 3, kw: 3, stride: 1, pad: 1,
    };
    let mut rng = Rng::new(0xB13);
    let mut x = Tensor4::zeros(1, 3, 6, 6);
    x.fill_random_ints(&mut rng, 0, 256);
    let f = TernaryFilter::new(3, 3, 3, 3, rng.ternary_vec(3 * 27, 0.5));
    let want = conv2d_ternary(&x, &f, 1, 1);
    for sa in [
        fat_imc::circuit::sense_amp::SaKind::Fat,
        fat_imc::circuit::sense_amp::SaKind::ParaPim,
        fat_imc::circuit::sense_amp::SaKind::GraphS,
        fat_imc::circuit::sense_amp::SaKind::SttCim,
    ] {
        let mut cfg = ChipConfig::fat();
        cfg.sa_kind = sa;
        let run = FatChip::new(cfg).run_conv_layer(&x, &f, &layer);
        assert_eq!(run.output.data, want.data, "{sa:?}");
    }
}

#[test]
fn workload_models_serve_byte_identically_across_every_path() {
    // The op-IR tentpole contract, end to end: a ternary transformer
    // block (GEMMs + attention epilogue) and a mobilenet-style backbone
    // (grouped + pointwise convs) must produce byte-identical outputs on
    // (1) the single-chip oracle, (2) the auto-planned hybrid fabric,
    // (3) the threaded hybrid server, and (4) the continuous-batching
    // serving engine — with register writes conserved across chips.
    use fat_imc::coordinator::engine::{
        EngineConfig, EngineRequest, SchedPolicy, ServingEngine, SloClass,
    };
    use fat_imc::coordinator::model::ModelSpec;
    use fat_imc::coordinator::server::{InferenceServer, Request, ServingMode};
    use fat_imc::coordinator::session::{op_wreg_footprint, ChipSession};
    use fat_imc::coordinator::tensor_parallel::{plan_auto, TensorParallelSession};
    use fat_imc::mapping::schemes::HwParams;

    let specs = [
        ModelSpec::synthetic_transformer(6, 8, 2, 2, 0.5, 0x1A01),
        ModelSpec::synthetic_mobilenet(1, 16, 6, 0.5, 0x1A02, 4),
    ];
    for spec in specs {
        // Shrink the register files so the planner must actually shard:
        // ~60% of the model, but never below the largest single layer
        // (the transformer's attention layers cannot be KN-split).
        let full = ChipConfig::fat();
        let planner = full.planner();
        let footprints: Vec<u64> =
            spec.layers.iter().map(|ls| op_wreg_footprint(&ls.op, &planner)).collect();
        let total: u64 = footprints.iter().sum();
        let biggest = *footprints.iter().max().expect("at least one layer");
        // (few CMAs so the per-CMA rounding can't hand back the whole
        // model's worth of registers on these tiny geometries)
        let mut cfg = full;
        cfg.cmas = 8;
        cfg.wreg_entries_per_cma =
            (((total * 60 / 100).max(biggest)) as usize).div_ceil(cfg.cmas).max(1);
        let hw = HwParams::default();

        let mut big = cfg;
        big.wreg_entries_per_cma = big.wreg_entries_per_cma.max(1 << 20);
        let mut oracle = ChipSession::new(big, spec.clone()).expect("oracle session");
        let mut rng = Rng::new(0x1A03);
        let xs: Vec<Tensor4> = (0..3).map(|_| spec.random_input(&mut rng)).collect();
        let want: Vec<_> =
            xs.iter().map(|x| oracle.infer(x).expect("oracle inference")).collect();

        // (2) the auto-planned hybrid fabric, inline
        let plan = (2..=8)
            .find_map(|c| plan_auto(&cfg, &spec, c, &hw).ok())
            .expect("a hybrid plan within 8 chips");
        assert!(plan.chips() >= 2, "{}: the shrunken chip must force multi-chip", spec.name);
        let mut tp = TensorParallelSession::new(cfg, spec.clone(), plan.clone(), hw)
            .expect("plan fits the small chips");
        assert_eq!(
            tp.loading_total().weight_reg_writes,
            oracle.loading().weight_reg_writes,
            "{}: register writes must be conserved across chips",
            spec.name
        );
        let tp_outs: Vec<_> = xs
            .iter()
            .map(|x| {
                let mut ho = tp.infer(x).expect("hybrid inference");
                ho.outs.pop().expect("one request in, one output out")
            })
            .collect();
        for (i, (got, w)) in tp_outs.iter().zip(&want).enumerate() {
            assert_eq!(
                got.features.data, w.features.data,
                "{}: request {i} hybrid features diverged from the oracle",
                spec.name
            );
            assert_eq!(got.logits, w.logits, "{}: request {i} logits diverged", spec.name);
        }

        // (3) the threaded hybrid server: byte-identical outputs AND
        // metrics to the inline session running the same plan
        let server = InferenceServer::start_with_hw(
            cfg,
            ServingMode::Hybrid { plan: plan.clone(), max_batch: 1 },
            spec.clone(),
            hw,
        )
        .expect("hybrid server starts");
        for (id, x) in xs.iter().enumerate() {
            server.submit(Request { id: id as u64, x: x.clone() }).expect("submit");
        }
        let mut responses = server
            .collect_timeout(xs.len(), std::time::Duration::from_secs(600))
            .expect("all submitted requests must come back");
        server.shutdown();
        responses.sort_by_key(|r| r.id);
        for (r, w) in responses.iter().zip(&tp_outs) {
            assert_eq!(r.features.data, w.features.data, "{}: server features", spec.name);
            assert_eq!(r.logits, w.logits, "{}: server logits", spec.name);
            assert_eq!(r.metrics, w.metrics, "{}: server metrics", spec.name);
        }

        // (4) the serving engine on the same plan: replay its exact fused
        // windows through a fresh inline session — outputs and metrics
        // must match, and the features must still equal the oracle's
        let trace: Vec<EngineRequest> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| EngineRequest {
                id: i as u64,
                x: x.clone(),
                class: SloClass::Batch,
                arrival_us: 0.0,
                deadline_us: 1e12,
            })
            .collect();
        let mut engine = ServingEngine::new(
            cfg,
            spec.clone(),
            plan,
            hw,
            SchedPolicy::SloEdf,
            EngineConfig { max_batch: 2, queue_windows: 4, queue_depth: Some(16) },
        )
        .expect("engine loads");
        let report = engine.run_trace(trace).expect("trace serves");
        assert_eq!(report.stats.served, xs.len() as u64, "{}: nothing shed", spec.name);
        let mut replay_outs = Vec::new();
        for window in &report.batch_log {
            let refs: Vec<&Tensor4> = window.iter().map(|&id| &xs[id as usize]).collect();
            let mut ho = tp.infer_many(&refs).expect("replay window");
            replay_outs.append(&mut ho.outs);
        }
        assert_eq!(report.responses.len(), replay_outs.len());
        for (r, w) in report.responses.iter().zip(&replay_outs) {
            assert_eq!(r.features.data, w.features.data, "{}: engine features", spec.name);
            assert_eq!(r.logits, w.logits, "{}: engine logits", spec.name);
            assert_eq!(r.metrics, w.metrics, "{}: engine metrics", spec.name);
        }
        for r in &report.responses {
            assert_eq!(
                r.features.data, want[r.id as usize].features.data,
                "{}: engine request {} diverged from the oracle",
                spec.name, r.id
            );
        }
    }
}

#[test]
fn gemm_path_matches_the_python_ternary_gemm_golden_vectors() {
    // Committed fixture from `python/tools/gen_gemm_golden.py`: a small
    // `y = x @ w` computed the way the L1 Pallas kernel
    // (`python/compile/kernels/ternary_gemm.py`) computes it — two masked
    // accumulations and one subtraction.  All values are integers < 2^24,
    // so the f32 interchange is exact and the comparison is bit-for-bit.
    use fat_imc::nn::ops::GemmLayer;

    let text = include_str!("golden/ternary_gemm.golden");
    let (mut m, mut k, mut n) = (0usize, 0usize, 0usize);
    let (mut x, mut w, mut y): (Vec<f32>, Vec<i8>, Vec<f32>) = (vec![], vec![], vec![]);
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match it.next().unwrap() {
            "m" => m = it.next().unwrap().parse().unwrap(),
            "k" => k = it.next().unwrap().parse().unwrap(),
            "n" => n = it.next().unwrap().parse().unwrap(),
            "x" => x = it.map(|v| v.parse().unwrap()).collect(),
            "w" => w = it.map(|v| v.parse().unwrap()).collect(),
            "y" => y = it.map(|v| v.parse().unwrap()).collect(),
            other => panic!("unknown golden tag `{other}`"),
        }
    }
    assert_eq!(x.len(), m * k, "fixture x shape");
    assert_eq!(w.len(), k * n, "fixture w shape");
    assert_eq!(y.len(), m * n, "fixture y shape");

    // the lowered conv consumes (1, k, m, 1): channel kk holds x column kk
    let gemm = GemmLayer { name: "golden", b: 1, m, k, n };
    let layer = gemm.lower();
    let mut xt = Tensor4::zeros(1, k, m, 1);
    for mi in 0..m {
        for kk in 0..k {
            xt.data[kk * m + mi] = x[mi * k + kk];
        }
    }
    // filter row ni is w's column ni (fixture w is row-major k x n)
    let mut wt = vec![0i8; n * k];
    for kk in 0..k {
        for ni in 0..n {
            wt[ni * k + kk] = w[kk * n + ni];
        }
    }
    let f = TernaryFilter::new(n, k, 1, 1, wt);
    let run = FatChip::new(ChipConfig::fat()).run_conv_layer(&xt, &f, &layer);
    for mi in 0..m {
        for ni in 0..n {
            assert_eq!(
                run.output.data[ni * m + mi],
                y[mi * n + ni],
                "y[{mi}][{ni}] diverged from the python kernel's golden value"
            );
        }
    }
    // the in-tree reference conv agrees with both sides of the interchange
    assert_eq!(run.output.data, conv2d_ternary(&xt, &f, 1, 0).data);
}

#[test]
fn cli_workload_smoke() {
    // `fat workload --net ...` prints the op-IR table and serves the
    // model; --auto self-checks bit-exactness + register-write
    // conservation vs the oracle and --serve replays through the hybrid
    // server (a divergence exits non-zero).
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe)
        .args(["workload", "--net", "transformer", "--requests", "2"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "workload transformer failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("op IR"), "{text}");
    assert!(text.contains("gemm"), "{text}");
    assert!(text.contains("+attn(2)"), "{text}");
    assert!(text.contains("served 2 requests"), "{text}");

    let out = std::process::Command::new(exe)
        .args([
            "workload", "--net", "mobilenet", "--input", "8", "--width", "4", "--requests",
            "2", "--auto", "--chips", "3", "--serve",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "workload mobilenet --auto --serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("grouped conv"), "{text}");
    assert!(text.contains("register-write conservation"), "{text}");
    assert!(text.contains("bit-identical to the single-chip oracle"), "{text}");
    assert!(text.contains("replaying the plan through the hybrid server"), "{text}");

    // flag discipline: bad nets and orphaned flags are clean errors
    let out = std::process::Command::new(exe)
        .args(["workload", "--net", "alexnet"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("transformer"));
    let out = std::process::Command::new(exe)
        .args(["workload", "--net", "transformer", "--serve"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--auto"));
    let out = std::process::Command::new(exe)
        .args(["workload", "--net", "transformer", "--chips", "2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn committed_bench_baselines_round_trip_as_json() {
    // Every committed `BENCH_*.baseline.json` must stay parseable as
    // strict JSON (the line-oriented `load_baseline` reader is forgiving;
    // this gate is not) and structurally sound: a non-empty
    // `measurements` array whose entries carry a string label and a
    // numeric median.  `schema_version` is optional — the committed
    // baselines predate versioning and read as version 1 — but when
    // present it must not exceed the writer's version.
    use fat_imc::bench_harness::{load_baseline, BenchRun, BENCH_SCHEMA_VERSION};
    use fat_imc::minijson;

    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/");
    let mut seen = 0;
    for entry in std::fs::read_dir(root).unwrap() {
        let name = entry.unwrap().file_name().into_string().unwrap();
        if !(name.starts_with("BENCH_") && name.ends_with(".baseline.json")) {
            continue;
        }
        seen += 1;
        let path = format!("{root}{name}");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = minijson::parse(&text).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e:#}"));
        assert!(doc.get("name").and_then(|v| v.as_str()).is_some(), "{name}: missing name");
        let version =
            doc.get("schema_version").and_then(|v| v.as_f64()).unwrap_or(1.0) as u64;
        assert!(version <= BENCH_SCHEMA_VERSION, "{name}: schema_version {version} too new");
        let ms = doc
            .get("measurements")
            .and_then(|v| v.as_arr())
            .unwrap_or_else(|| panic!("{name}: missing measurements array"));
        assert!(!ms.is_empty(), "{name}: no measurements");
        for m in ms {
            let label = m
                .get("label")
                .and_then(|v| v.as_str())
                .unwrap_or_else(|| panic!("{name}: measurement without label"));
            assert!(
                m.get("median_ns").and_then(|v| v.as_f64()).is_some(),
                "{name}: {label}: median_ns not numeric"
            );
        }
        // the quick line-oriented reader and the strict parser must agree
        // on what the baseline contains
        let quick = load_baseline(&path).unwrap_or_else(|| panic!("{name}: load_baseline"));
        assert_eq!(quick.len(), ms.len(), "{name}: reader disagreement");
    }
    assert!(seen >= 4, "expected the committed baselines, found {seen}");

    // and a freshly written record round-trips at the current version
    let mut run = BenchRun::new("roundtrip");
    run.check("structural", true, String::new());
    let doc = minijson::parse(&run.to_json()).expect("fresh record parses");
    assert_eq!(
        doc.get("schema_version").and_then(|v| v.as_f64()),
        Some(BENCH_SCHEMA_VERSION as f64)
    );
}

#[test]
fn cli_loadgen_smoke() {
    // `fat loadgen` replays one deterministic Poisson trace through the
    // SLO engine and the dequeue-fusion baseline; its in-binary gates
    // (request conservation, engine goodput >= baseline) exit non-zero on
    // failure, so a clean exit IS the goodput sanity check.  Tiny model +
    // modest overload keeps the debug binary fast.
    let exe = env!("CARGO_BIN_EXE_fat");
    let out = std::process::Command::new(exe)
        .args([
            "loadgen", "--load", "4", "--seed", "7", "--input", "8", "--scale", "64",
            "--classes", "5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "loadgen failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("slo-edf"), "{text}");
    assert!(text.contains("fifo-dequeue"), "{text}");
    assert!(text.contains("goodput"), "{text}");
    assert!(text.contains("loadgen OK"), "{text}");

    // flag discipline: typos are rejected, bad rates are clean errors
    let out = std::process::Command::new(exe)
        .args(["loadgen", "--laod", "3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = std::process::Command::new(exe)
        .args(["loadgen", "--rate", "-5"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("rate"), "{err}");
}
